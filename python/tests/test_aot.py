"""AOT pipeline tests: HLO-text lowering, manifest schema, param blobs,
and the scaling study."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile import scaling_study
from compile.aot import Emitter, lowered_to_hlo_text, model_cfg, DEFAULT_TC


class TestLowering:
    def test_hlo_text_is_parseable_module(self):
        lowered = jax.jit(lambda x: (x @ x.T,)).lower(
            jax.ShapeDtypeStruct((4, 4), jnp.float32)
        )
        text = lowered_to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text
        # return_tuple=True: root computation returns a tuple
        assert "tuple" in text.lower()

    def test_attention_lowering_has_right_params(self):
        from compile.kernels import ref

        spec = jax.ShapeDtypeStruct((64, 8), jnp.float32)
        lowered = jax.jit(lambda q, k, v: ref.taylor_efficient(q, k, v, 1.0)).lower(
            spec, spec, spec
        )
        text = lowered_to_hlo_text(lowered)
        # three f32[64,8] parameters
        assert text.count("f32[64,8]{1,0} parameter(") >= 3


class TestEmitter:
    def test_emitter_writes_artifact_and_manifest(self, tmp_path):
        em = Emitter(str(tmp_path))
        em.attention("efficient", 64, 8)
        em.finish()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["version"] == 1
        entry = manifest["artifacts"]["attn_efficient_n64_d8"]
        assert entry["kind"] == "attention"
        assert entry["inputs"][0]["shape"] == [64, 8]
        assert (tmp_path / entry["path"]).exists()

    def test_train_artifact_io_spec_consistent(self, tmp_path):
        em = Emitter(str(tmp_path))
        cfg = model_cfg(
            "listops", "efficient", name="tiny_listops",
            seq_len=32, depth=1, d_embed=16, heads=2,
        )
        em.train(cfg, DEFAULT_TC, batch=2, seed=0)
        em.finish()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        entry = manifest["artifacts"]["tiny_listops_train_b2"]
        n_leaves = len(entry["params"])
        # inputs = 3*leaves + step + tokens + labels
        assert len(entry["inputs"]) == 3 * n_leaves + 3
        # outputs = 3*leaves + loss + acc
        assert len(entry["outputs"]) == 3 * n_leaves + 2
        # params blob has exactly num_params f32s
        blob = (tmp_path / entry["params_bin"]).read_bytes()
        assert len(blob) == 4 * entry["num_params"]

    def test_infer_params_deterministic_across_variants(self, tmp_path):
        em = Emitter(str(tmp_path))
        for variant in ("direct", "efficient"):
            cfg = model_cfg(
                "listops", variant, name=f"tv_{variant}",
                seq_len=32, depth=1, d_embed=16, heads=2,
            )
            em.infer(cfg, batch=1, seed=7)
        em.finish()
        a = (tmp_path / "tv_direct_infer_b1_n32.params.bin").read_bytes()
        b = (tmp_path / "tv_efficient_infer_b1_n32.params.bin").read_bytes()
        assert a == b, "same seed must give identical params across variants"


class TestParamsLayout:
    def test_flatten_paths_align_with_leaves(self):
        cfg = model_cfg("pixel", "efficient")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        leaves, paths, _ = aot.flatten_params(params)
        assert len(leaves) == len(paths)
        # Paths are unique and sorted within each dict level.
        assert len(set(paths)) == len(paths)
        # Spot-check a couple of known leaves exist.
        assert "tok_embed" in paths
        assert any(p.endswith("/tau") for p in paths)


class TestScalingStudy:
    def test_slopes_match_table1(self):
        result = scaling_study.run_study(d=8, ns=[64, 256, 1024], reps=2)
        assert abs(result["slopes"]["a_mod"] - 1.0) < 0.2
        assert abs(result["slopes"]["y_denom"] - 1.0) < 0.2
        assert abs(result["slopes"]["y"] + 0.5) < 0.3

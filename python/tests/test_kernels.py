"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The CORE correctness signal of the compile path — every kernel must
match ``ref.py`` to float tolerance across a hypothesis-driven sweep of
shapes and parameters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.softmax_attn import softmax_attention_pallas
from compile.kernels.tsa_direct import taylor_direct_pallas
from compile.kernels.tsa_efficient import taylor_efficient_pallas


def qkv(n, d, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    return (
        jax.random.normal(kq, (n, d), dtype),
        jax.random.normal(kk, (n, d), dtype),
        jax.random.normal(kv, (n, d), dtype),
    )


def assert_close(a, b, atol=1e-5, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# Oracle self-consistency (Section 3.2: both forms are the same function)
# ---------------------------------------------------------------------------


class TestOracle:
    def test_taylor_softmax_is_distribution(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (7, 11))
        p = ref.taylor_softmax(x, order=2)
        assert np.all(np.asarray(p) >= 0)
        assert_close(jnp.sum(p, axis=-1), jnp.ones(7))

    def test_taylor_softmax_order2_values(self):
        # hand-computed: x = [0, 1] -> [1, 2.5] -> normalize
        x = jnp.array([[0.0, 1.0]])
        p = ref.taylor_softmax(x, order=2)
        assert_close(p, jnp.array([[1.0 / 3.5, 2.5 / 3.5]]))

    @pytest.mark.parametrize("n,d", [(8, 4), (33, 8), (128, 16), (65, 32)])
    def test_efficient_equals_direct(self, n, d):
        q, k, v = qkv(n, d, seed=n + d)
        assert_close(
            ref.taylor_efficient(q, k, v, 1.3),
            ref.taylor_direct(q, k, v, 1.3),
            atol=1e-4, rtol=1e-3,
        )

    @pytest.mark.parametrize("n,d", [(16, 4), (64, 8)])
    def test_unnormalized_efficient_equals_plain_direct(self, n, d):
        q, k, v = qkv(n, d, seed=3)
        q, k = 0.3 * q, 0.3 * k
        assert_close(
            ref.taylor_efficient_unnormalized(q, k, v),
            ref.taylor_direct_plain(q, k, v),
            atol=1e-4, rtol=1e-3,
        )

    def test_constant_values_pass_through(self):
        # T-SM rows are a distribution => constant V is preserved.
        q, k, _ = qkv(32, 8, seed=5)
        v = jnp.full((32, 8), 2.5)
        for fn in (ref.taylor_direct_plain, lambda q, k, v: ref.taylor_efficient_unnormalized(q, k, v)):
            assert_close(fn(q, k, v), v, atol=1e-4)

    def test_normalized_invariant_to_input_scale(self):
        q, k, v = qkv(24, 8, seed=6)
        y1 = ref.taylor_efficient(q, k, v, 2.0)
        y2 = ref.taylor_efficient(100.0 * q, 0.01 * k, v, 2.0)
        assert_close(y1, y2, atol=1e-4, rtol=1e-3)

    def test_taylor_tracks_softmax_for_small_logits(self):
        # Approximation view ([Keles et al. 2023] error bounds): for
        # small scores the 2nd-order Taylor softmax ~ softmax.
        q, k, v = qkv(16, 8, seed=7)
        qs, ks = 0.1 * q, 0.1 * k
        soft = ref.softmax_attention(qs * (8**0.5), ks, v)  # undo 1/sqrt(d)
        taylor = ref.taylor_direct_plain(qs, ks, v)
        np.testing.assert_allclose(np.asarray(soft), np.asarray(taylor), atol=0.02)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle
# ---------------------------------------------------------------------------


class TestPallasKernels:
    @pytest.mark.parametrize("n,d,bn", [(128, 8, 64), (256, 16, 128), (128, 32, 32)])
    def test_efficient_kernel(self, n, d, bn):
        q, k, v = qkv(n, d, seed=n)
        assert_close(
            taylor_efficient_pallas(q, k, v, 1.1, block_n=bn),
            ref.taylor_efficient(q, k, v, 1.1),
            atol=1e-4, rtol=1e-3,
        )

    @pytest.mark.parametrize("n,d,bn", [(128, 8, 64), (256, 16, 128), (128, 32, 32)])
    def test_direct_kernel(self, n, d, bn):
        q, k, v = qkv(n, d, seed=n + 1)
        assert_close(
            taylor_direct_pallas(q, k, v, 1.1, block_n=bn),
            ref.taylor_direct(q, k, v, 1.1),
            atol=1e-4, rtol=1e-3,
        )

    @pytest.mark.parametrize("n,d,bn,bk", [(128, 8, 64, 64), (256, 16, 128, 64)])
    def test_softmax_kernel(self, n, d, bn, bk):
        q, k, v = qkv(n, d, seed=n + 2)
        assert_close(
            softmax_attention_pallas(q, k, v, block_n=bn, block_k=bk),
            ref.softmax_attention(q, k, v),
            atol=1e-5, rtol=1e-4,
        )

    def test_kernels_cross_agree(self):
        # direct and efficient kernels agree with each other directly.
        q, k, v = qkv(256, 16, seed=11)
        assert_close(
            taylor_efficient_pallas(q, k, v, 0.7),
            taylor_direct_pallas(q, k, v, 0.7),
            atol=1e-4, rtol=1e-3,
        )

    def test_block_size_invariance(self):
        q, k, v = qkv(256, 8, seed=12)
        y64 = taylor_efficient_pallas(q, k, v, 1.0, block_n=64)
        y256 = taylor_efficient_pallas(q, k, v, 1.0, block_n=256)
        assert_close(y64, y256, atol=1e-5, rtol=1e-4)

    def test_rejects_indivisible_n(self):
        q, k, v = qkv(100, 8, seed=13)
        with pytest.raises(AssertionError):
            taylor_efficient_pallas(q, k, v, 1.0, block_n=64)

    # Hypothesis sweep: random shapes, temperatures, magnitudes.
    @settings(max_examples=12, deadline=None)
    @given(
        nb=st.integers(1, 4),
        bn=st.sampled_from([32, 64]),
        d=st.sampled_from([4, 8, 16]),
        tau=st.floats(0.25, 4.0),
        scale=st.floats(0.1, 10.0),
        seed=st.integers(0, 2**16),
    )
    def test_efficient_kernel_hypothesis(self, nb, bn, d, tau, scale, seed):
        n = nb * bn
        q, k, v = qkv(n, d, seed=seed)
        y_kernel = taylor_efficient_pallas(scale * q, k, v, tau, block_n=bn)
        y_ref = ref.taylor_efficient(scale * q, k, v, tau)
        assert_close(y_kernel, y_ref, atol=1e-4, rtol=1e-3)

    @settings(max_examples=8, deadline=None)
    @given(
        nb=st.integers(1, 3),
        d=st.sampled_from([4, 8]),
        tau=st.floats(0.25, 4.0),
        seed=st.integers(0, 2**16),
    )
    def test_direct_kernel_hypothesis(self, nb, d, tau, seed):
        n = nb * 64
        q, k, v = qkv(n, d, seed=seed)
        assert_close(
            taylor_direct_pallas(q, k, v, tau, block_n=64),
            ref.taylor_direct(q, k, v, tau),
            atol=1e-4, rtol=1e-3,
        )


# ---------------------------------------------------------------------------
# Numerical behavior (Section 3.3, Table 1, Fig. 4)
# ---------------------------------------------------------------------------


class TestNumerics:
    def test_unnormalized_intermediates_grow_with_n(self):
        d = 8
        sizes = []
        for n in (128, 512):
            key = jax.random.PRNGKey(n)
            kq, kk, kv = jax.random.split(key, 3)
            mk = lambda k_: ref.normalize_rows(jax.random.normal(k_, (n, d)), 1.0)
            s = ref.intermediate_sizes(mk(kq), mk(kk), mk(kv))
            sizes.append(s)
        # A_mod and Y_denom grow ~linearly in N (Table 1).
        assert sizes[1]["a_mod"]["fro"] > 3.0 * sizes[0]["a_mod"]["fro"]
        assert sizes[1]["y_denom"]["row"] > 3.0 * sizes[0]["y_denom"]["row"]
        # final (normalized) output shrinks ~ sqrt(d/N)
        assert sizes[1]["y"]["row"] < sizes[0]["y"]["row"]

    def test_table1_a_mod_frobenius_law(self):
        # Paper Table 1: |A_mod| ~ (N+1)/sqrt(d) — Frobenius norm with
        # the un-scaled denominator column dominating.
        n, d = 1024, 16
        key = jax.random.PRNGKey(1)
        kq, kk, kv = jax.random.split(key, 3)
        mk = lambda k_: ref.normalize_rows(jax.random.normal(k_, (n, d)), 1.0)
        s = ref.intermediate_sizes(mk(kq), mk(kk), mk(kv))
        pred = (n + 1) / d**0.5
        assert 0.5 < s["a_mod"]["fro"] / pred < 2.0

    def test_unnormalized_overflows_in_f16(self):
        # Fig. 4 / App. B.1: the plain linearization overflows in low
        # precision for long sequences; the normalized version does not.
        n, d = 4096, 16
        key = jax.random.PRNGKey(2)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (n, d), jnp.float16) * 4
        k = jax.random.normal(kk, (n, d), jnp.float16) * 4
        v = jax.random.normal(kv, (n, d), jnp.float16) * 4
        y_plain = ref.taylor_efficient_unnormalized(q, k, v)
        assert not bool(jnp.all(jnp.isfinite(y_plain))), "expected overflow"
        y_norm = ref.taylor_efficient(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), 1.0
        ).astype(jnp.float16)
        assert bool(jnp.all(jnp.isfinite(y_norm)))

    def test_normalized_output_size_consistent_across_n(self):
        # Section 3.3 goal: output mean size independent of N.
        d = 16
        norms = []
        for n in (128, 1024):
            q, k, v = qkv(n, d, seed=n)
            y = ref.taylor_efficient(q, k, v, 1.0)
            norms.append(float(jnp.mean(jnp.linalg.norm(y, axis=-1))))
        ratio = norms[1] / norms[0]
        assert 0.5 < ratio < 2.0, norms

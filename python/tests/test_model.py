"""L2 tests: encoder shapes, variant interchangeability, gradients,
optimizer behavior, and the train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.aot import model_cfg, DEFAULT_TC
from compile.model import ModelConfig


def tiny_cfg(**over):
    base = dict(
        name="tiny", vocab_size=12, num_classes=3, seq_len=32, depth=2,
        d_embed=16, heads=2, mlp_ratio=2.0, variant="efficient",
    )
    base.update(over)
    return ModelConfig(**base)


def data(cfg, batch=4, seed=0):
    kt, kl = jax.random.split(jax.random.PRNGKey(seed))
    tokens = jax.random.randint(kt, (batch, cfg.seq_len), 0, cfg.vocab_size)
    labels = jax.random.randint(kl, (batch,), 0, cfg.num_classes)
    return tokens, labels


class TestForward:
    @pytest.mark.parametrize("variant", ["softmax", "direct", "efficient"])
    def test_shapes(self, variant):
        cfg = tiny_cfg(variant=variant)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        tokens, _ = data(cfg)
        logits = M.forward(cfg, params, tokens)
        assert logits.shape == (4, cfg.num_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_direct_equals_efficient_model_level(self):
        # The whole encoder output is identical under the two variants
        # (same parameters): the paper's interchangeability claim at
        # model scale.
        cfg_d = tiny_cfg(variant="direct")
        cfg_e = tiny_cfg(variant="efficient")
        params = M.init_params(jax.random.PRNGKey(1), cfg_d)
        tokens, _ = data(cfg_d)
        ld = M.forward(cfg_d, params, tokens)
        le = M.forward(cfg_e, params, tokens)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(le), atol=1e-4, rtol=1e-3)

    def test_pallas_path_matches_ref_path(self):
        cfg_ref = tiny_cfg(variant="efficient", use_pallas=False)
        cfg_pal = tiny_cfg(variant="efficient", use_pallas=True)
        params = M.init_params(jax.random.PRNGKey(2), cfg_ref)
        tokens, _ = data(cfg_ref)
        np.testing.assert_allclose(
            np.asarray(M.forward(cfg_ref, params, tokens)),
            np.asarray(M.forward(cfg_pal, params, tokens)),
            atol=1e-4, rtol=1e-3,
        )

    def test_conv_embed_changes_output_and_params(self):
        cfg_lin = tiny_cfg(embed="linear")
        cfg_conv = tiny_cfg(embed="conv")
        p_lin = M.init_params(jax.random.PRNGKey(3), cfg_lin)
        p_conv = M.init_params(jax.random.PRNGKey(3), cfg_conv)
        assert M.num_params(p_conv) > M.num_params(p_lin)
        assert "conv0_w" in p_conv and "conv0_w" not in p_lin
        tokens, _ = data(cfg_conv)
        logits = M.forward(cfg_conv, p_conv, tokens)
        assert logits.shape == (4, 3)

    def test_learned_pos_embedding(self):
        cfg = tiny_cfg(pos="learned")
        params = M.init_params(jax.random.PRNGKey(4), cfg)
        assert "pos_embed" in params
        tokens, _ = data(cfg)
        assert M.forward(cfg, params, tokens).shape == (4, 3)

    def test_token_permutation_changes_logits(self):
        # Positional encoding must break permutation invariance.
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(5), cfg)
        tokens, _ = data(cfg)
        perm = jnp.flip(tokens, axis=1)
        l1 = M.forward(cfg, params, tokens)
        l2 = M.forward(cfg, params, perm)
        assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4

    def test_qk_scores_shape(self):
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(6), cfg)
        tokens, _ = data(cfg, batch=1)
        s = M.qk_scores_single(cfg, params, tokens[0], layer=1)
        assert s.shape == (cfg.heads, cfg.seq_len, cfg.seq_len)
        # normalized q (scale tau) x normalized k: |scores| <= tau
        tau_max = float(jnp.max(params["block1"]["tau"]))
        assert float(jnp.max(jnp.abs(s))) <= tau_max + 1e-4


class TestTrainStep:
    def test_loss_decreases_on_fixed_batch(self):
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(7), cfg)
        m, v = T.init_opt_state(params)
        tokens, labels = data(cfg, batch=8, seed=7)
        tc = DEFAULT_TC
        step_fn = jax.jit(lambda *a: T.train_step(cfg, tc, *a))
        loss0 = None
        state = (params, m, v)
        for i in range(30):
            p, mm, vv, loss, _ = step_fn(*state, jnp.asarray(i, jnp.int32), tokens, labels)
            state = (p, mm, vv)
            if loss0 is None:
                loss0 = float(loss)
        assert float(loss) < loss0, (loss0, float(loss))

    def test_gradients_flow_to_all_params(self):
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(8), cfg)
        tokens, labels = data(cfg, batch=4, seed=8)
        grads = jax.grad(lambda p: T.loss_and_acc(cfg, p, tokens, labels)[0])(params)
        flat, _ = jax.tree_util.tree_flatten(grads)
        nonzero = sum(bool(jnp.any(g != 0)) for g in flat)
        assert nonzero >= len(flat) - 1, f"{nonzero}/{len(flat)} grads nonzero"

    def test_tau_is_trained(self):
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(9), cfg)
        tokens, labels = data(cfg, batch=4, seed=9)
        grads = jax.grad(lambda p: T.loss_and_acc(cfg, p, tokens, labels)[0])(params)
        assert bool(jnp.any(grads["block0"]["tau"] != 0))

    def test_lamb_vs_adamw_differ(self):
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(10), cfg)
        m, v = T.init_opt_state(params)
        tokens, labels = data(cfg, batch=4, seed=10)
        s = jnp.asarray(100, jnp.int32)  # past warmup
        out_lamb = T.train_step(cfg, T.TrainConfig(optimizer="lamb"), params, m, v, s, tokens, labels)
        out_adam = T.train_step(cfg, T.TrainConfig(optimizer="adamw"), params, m, v, s, tokens, labels)
        d_lamb = out_lamb[0]["block0"]["wqkv"] - params["block0"]["wqkv"]
        d_adam = out_adam[0]["block0"]["wqkv"] - params["block0"]["wqkv"]
        assert float(jnp.max(jnp.abs(d_lamb - d_adam))) > 1e-9

    def test_lr_schedule(self):
        tc = T.TrainConfig(lr=1.0, warmup_steps=10, total_steps=110)
        lr0 = float(T.lr_at(tc, jnp.asarray(0, jnp.int32)))
        lr_w = float(T.lr_at(tc, jnp.asarray(10, jnp.int32)))
        lr_mid = float(T.lr_at(tc, jnp.asarray(60, jnp.int32)))
        lr_end = float(T.lr_at(tc, jnp.asarray(110, jnp.int32)))
        assert lr0 == 0.0
        assert abs(lr_w - 1.0) < 1e-6
        assert 0.4 < lr_mid < 0.6
        assert lr_end < 1e-6

    def test_eval_step_matches_forward(self):
        cfg = tiny_cfg()
        params = M.init_params(jax.random.PRNGKey(11), cfg)
        tokens, labels = data(cfg, batch=6, seed=11)
        loss, acc = T.eval_step(cfg, params, tokens, labels)
        logits = M.forward(cfg, params, tokens)
        manual_acc = float(jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32)))
        assert abs(float(acc) - manual_acc) < 1e-6
        assert float(loss) > 0

    def test_norm_ablation_stages_distinct(self):
        tokens, labels = data(tiny_cfg(), batch=4, seed=12)
        logits = {}
        for stage in ("plain", "input", "full"):
            cfg = tiny_cfg(variant="efficient", norm_stage=stage)
            params = M.init_params(jax.random.PRNGKey(13), cfg)
            logits[stage] = M.forward(cfg, params, tokens)
        assert float(jnp.max(jnp.abs(logits["plain"] - logits["full"]))) > 1e-5
        assert float(jnp.max(jnp.abs(logits["input"] - logits["full"]))) > 1e-6


class TestConfigRegistry:
    def test_registry_configs_valid(self):
        for task in ("listops", "pixel", "textbytes"):
            for variant in ("softmax", "direct", "efficient"):
                cfg = model_cfg(task, variant)
                assert cfg.d_embed % cfg.heads == 0
                params = M.init_params(jax.random.PRNGKey(0), cfg)
                assert M.num_params(params) > 0

    def test_head_override(self):
        cfg = model_cfg("pixel", "efficient", name="pixel_h16", heads=16)
        assert cfg.heads == 16 and cfg.head_dim == 4

"""Efficient-TaylorShift as a Pallas kernel (Algorithm 1).

TPU-shaped formulation (see DESIGN.md §Hardware-Adaptation): instead of
porting a CUDA threadblock layout, the kernel expresses the paper's
insight — stream the sequence once, accumulating a tiny
``(d^2+d+1) x (d+1)`` moment matrix in VMEM — with two ``pallas_call``
grids over N-blocks:

* **moments kernel** — for each K/V block, form the feature map
  ``phi(k) = [k (x) k, k, 1]`` (the degree-2 polynomial features of the
  Taylor expansion) and accumulate ``A_full += phi(K_blk)^T V_blk``.
  ``A_full`` lives in the output block, mapped to the same block for
  every grid step (standard Pallas accumulator pattern); this is the
  Flash-style partial-``A_mod`` schedule the paper's App. D.2 suggests.
* **apply kernel** — for each Q block, ``Y_hat_blk = phi_c(q) @ A_full``
  where ``phi_c(q) = [1/2 q (x) q, a^2 q, a^4 1]`` carries the
  rescaled Taylor coefficients (footnote 7), then divide by the
  denominator column.

``interpret=True`` everywhere: the CPU PJRT client cannot execute
Mosaic custom-calls; on a real TPU the same BlockSpecs compile natively
(block-size VMEM analysis in ``analysis::roofline``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["taylor_efficient_pallas"]


def _moments_kernel(k_ref, v_ref, a_ref):
    """Accumulate A_full += [K (x) K ; K ; 1]^T @ V over N-blocks."""
    i = pl.program_id(0)
    bn, d = k_ref.shape
    k = k_ref[...]
    v = v_ref[...]
    kbox = (k[:, :, None] * k[:, None, :]).reshape(bn, d * d)
    ones = jnp.ones((bn, 1), dtype=k.dtype)
    phi = jnp.concatenate([kbox, k, ones], axis=-1)  # (bn, d^2+d+1)
    update = phi.T @ v  # (d^2+d+1, d+1)

    @pl.when(i == 0)
    def _init():
        a_ref[...] = jnp.zeros_like(a_ref)

    a_ref[...] += update


def _apply_kernel(q_ref, a_ref, y_ref, *, alpha: float):
    """Y_hat_blk = [1/2 Q (x) Q ; a^2 Q ; a^4 1] @ A_full, then divide."""
    bn, d = q_ref.shape
    q = q_ref[...]
    qbox = (q[:, :, None] * q[:, None, :]).reshape(bn, d * d)
    ones = jnp.ones((bn, 1), dtype=q.dtype)
    phi = jnp.concatenate(
        [0.5 * qbox, (alpha**2) * q, (alpha**4) * ones], axis=-1
    )
    y_hat = phi @ a_ref[...]  # (bn, d+1)
    y_ref[...] = y_hat[:, 1:] / y_hat[:, :1]


@functools.partial(jax.jit, static_argnames=("block_n",))
def taylor_efficient_pallas(q, k, v, tau=1.0, *, block_n: int = 128):
    """Efficient-TaylorShift with normalization, Pallas-tiled over N.

    Matches :func:`ref.taylor_efficient` to float tolerance. ``N`` must
    be divisible by ``block_n`` (callers pad to the bucket grid; the
    coordinator's batcher guarantees this on the serving path).
    """
    n, d = q.shape
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    nb = n // block_n
    alpha = float(d**0.25)

    # Normalization prologue (cheap, fused by XLA) — Lines 4-6.
    ones_col = jnp.full((n, 1), (d / n) ** 0.5, dtype=v.dtype)
    v_aug = jnp.concatenate([ones_col, v], axis=-1) / n
    qn = ref.normalize_rows(q, alpha * tau)
    kn = ref.normalize_rows(k, alpha)

    dd = d * d + d + 1
    a_full = pl.pallas_call(
        _moments_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d + 1), lambda i: (i, 0)),
        ],
        # Every grid step maps to the same output block => accumulator.
        out_specs=pl.BlockSpec((dd, d + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((dd, d + 1), q.dtype),
        interpret=True,
    )(kn, v_aug)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, alpha=alpha),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((dd, d + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=True,
    )(qn, a_full)
    return y

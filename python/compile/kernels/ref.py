"""Pure-jnp oracles for TaylorShift attention.

These are the correctness ground truth for the Pallas kernels (pytest
compares kernel outputs against these) and double as the fast lowering
path used inside the L2 model when the Pallas interpreter would be
overkill (the math is identical; see DESIGN.md §Hardware-Adaptation).

All functions operate on single-head inputs ``q, k, v: (N, d)``; batch
and head dimensions are added by ``jax.vmap`` at the call site.

Paper: Nauen et al., *TaylorShift* (2024) — Sections 3.1-3.3,
Algorithm 1.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "taylor_softmax",
    "taylor_direct_plain",
    "taylor_direct",
    "taylor_efficient",
    "taylor_efficient_unnormalized",
    "softmax_attention",
    "normalize_rows",
    "intermediate_sizes",
]


def taylor_softmax(x: jnp.ndarray, order: int = 2) -> jnp.ndarray:
    """Row-wise Taylor softmax: normalize(sum_{n<=order} x^n / n!).

    For even ``order`` the result is a probability distribution
    (positive, rows sum to 1) — Section 3.1.
    """
    acc = jnp.ones_like(x)
    term = jnp.ones_like(x)
    fact = 1.0
    for n in range(1, order + 1):
        fact *= n
        term = term * x
        acc = acc + term / fact
    return acc / jnp.sum(jnp.abs(acc), axis=-1, keepdims=True)


def normalize_rows(x: jnp.ndarray, scale) -> jnp.ndarray:
    """l2-normalize the last axis and multiply by ``scale``."""
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return scale * x / jnp.maximum(norm, 1e-12)


def taylor_direct_plain(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Direct TaylorShift, Eq. (1): T-SM(QK^T) V — no normalization."""
    scores = q @ k.T
    return taylor_softmax(scores, order=2) @ v


def taylor_direct(q, k, v, tau=1.0) -> jnp.ndarray:
    """Direct TaylorShift with the Section-3.3 normalization scheme.

    Interchangeable with :func:`taylor_efficient` — identical output up
    to float rounding.
    """
    n, d = q.shape
    qn = normalize_rows(q, tau)
    kn = normalize_rows(k, 1.0)
    return taylor_direct_plain(qn, kn, v) * jnp.asarray((n / d) ** 0.5, dtype=q.dtype)


def taylor_efficient(q, k, v, tau=1.0) -> jnp.ndarray:
    """Efficient TaylorShift — Algorithm 1 (with normalization).

    ``O(N d^3)`` time, ``O(N d^2)`` memory: the squared Gram term is
    linearized through the row-wise tensor product
    ``(QK^T)^(.2) V = Q^box2 ((K^box2)^T V)`` and evaluated
    right-to-left; nominator and denominator ride together by
    prepending a ones-column to V (pre-scaled by sqrt(d/N) so the final
    division also applies the output normalization — footnote 8).
    """
    n, d = q.shape
    alpha = d**0.25

    # Line 5: V <- (1/N) ((sqrt(d/N) 1_N) o V)
    ones_col = jnp.full((n, 1), (d / n) ** 0.5, dtype=v.dtype)
    v_aug = jnp.concatenate([ones_col, v], axis=-1) / n

    # Line 6: Q <- alpha tau Q/|Q|, K <- alpha K/|K|
    qn = normalize_rows(q, alpha * tau)
    kn = normalize_rows(k, alpha)

    # Line 7: A_mod <- (K box K)^T V    [d^2 x (d+1)]
    kbox = (kn[:, :, None] * kn[:, None, :]).reshape(n, d * d)
    a_mod = kbox.T @ v_aug

    # Line 8: Y_hat <- (Q box Q) A_mod
    qbox = (qn[:, :, None] * qn[:, None, :]).reshape(n, d * d)
    y_hat = qbox @ a_mod

    # Line 9: Y_hat <- 1/2 Y_hat + alpha^2 Q (K^T V) + alpha^4 sum_i V_i
    y_hat = (
        0.5 * y_hat
        + (alpha**2) * (qn @ (kn.T @ v_aug))
        + (alpha**4) * jnp.sum(v_aug, axis=0)[None, :]
    )

    # Lines 10-11: split denominator, Hadamard division.
    return y_hat[:, 1:] / y_hat[:, :1]


def taylor_efficient_unnormalized(q, k, v) -> jnp.ndarray:
    """The naive linearization without the normalization scheme.

    Mathematically equals :func:`taylor_direct_plain`; numerically its
    intermediates grow with N per Table 1 and overflow in low precision
    (Fig. 4 / Appendix B.1). Kept for the Table 4 ablation and the
    divergence demo.
    """
    n, d = q.shape
    v_aug = jnp.concatenate([jnp.ones((n, 1), dtype=v.dtype), v], axis=-1)
    kbox = (k[:, :, None] * k[:, None, :]).reshape(n, d * d)
    a_mod = kbox.T @ v_aug
    qbox = (q[:, :, None] * q[:, None, :]).reshape(n, d * d)
    y_hat = 0.5 * (qbox @ a_mod) + q @ (k.T @ v_aug) + jnp.sum(v_aug, axis=0)[None, :]
    return y_hat[:, 1:] / y_hat[:, :1]


def softmax_attention(q, k, v) -> jnp.ndarray:
    """Standard softmax attention with 1/sqrt(d) scaling (baseline)."""
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.asarray(d**0.5, dtype=q.dtype)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    weights = jnp.exp(scores)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights @ v


def intermediate_sizes(q, k, v):
    """Mean norms of the efficient pipeline's intermediates
    (unnormalized, unit-sphere inputs) — the Table 1 / Fig. 5 study.

    Returns a dict with mean row norms and full Frobenius norms; the
    scaling study (``compile/scaling_study.py``) fits the paper's
    candidate laws against these.
    """
    n, d = q.shape
    v_aug = jnp.concatenate([jnp.ones((n, 1), dtype=v.dtype), v], axis=-1)
    kbox = (k[:, :, None] * k[:, None, :]).reshape(n, d * d)
    a_mod = kbox.T @ v_aug
    qbox = (q[:, :, None] * q[:, None, :]).reshape(n, d * d)
    y_sq = qbox @ a_mod
    y_lin = q @ (k.T @ v_aug)
    y_hat = 0.5 * y_sq + y_lin + jnp.sum(v_aug, axis=0)[None, :]
    y_denom = y_hat[:, :1]
    y = y_hat[:, 1:] / y_denom

    def row_norm(x):
        return float(jnp.mean(jnp.linalg.norm(x, axis=-1)))

    def fro(x):
        return float(jnp.linalg.norm(x))

    return {
        "a_mod": {"row": row_norm(a_mod.T), "fro": fro(a_mod)},
        "squared_v": {"row": row_norm(y_sq[:, 1:]), "fro": fro(y_sq[:, 1:])},
        "linear_v": {"row": row_norm(y_lin[:, 1:]), "fro": fro(y_lin[:, 1:])},
        "y_denom": {"row": float(jnp.mean(jnp.abs(y_denom))), "fro": fro(y_denom)},
        "y": {"row": row_norm(y), "fro": fro(y)},
    }

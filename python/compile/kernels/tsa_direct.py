"""Direct-TaylorShift as a Pallas kernel.

Grid over row-blocks of the N x N score matrix: each step loads one
Q block plus the full K and V (valid for the short-sequence regime
``N < N0(d)`` where the direct variant is the faster choice — at d=64
and N=4096 the K/V VMEM residency is ~2 MiB), computes the fused
``1 + x + x^2/2`` scores, the row sums, and the V contraction in one
pass. Memory stays ``O(block_n * N)`` instead of ``O(N^2)``.

``interpret=True`` — see ``tsa_efficient.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["taylor_direct_pallas"]


def _direct_kernel(q_ref, k_ref, v_ref, y_ref, *, out_scale: float):
    q = q_ref[...]  # (bn, d)
    k = k_ref[...]  # (N, d)
    v = v_ref[...]  # (N, d)
    s = q @ k.T  # (bn, N)
    a = 1.0 + s + 0.5 * s * s
    denom = jnp.sum(a, axis=-1, keepdims=True)
    y_ref[...] = (a @ v) / denom * out_scale


@functools.partial(jax.jit, static_argnames=("block_n",))
def taylor_direct_pallas(q, k, v, tau=1.0, *, block_n: int = 128):
    """Direct-TaylorShift with normalization, Pallas row-block tiled.

    Matches :func:`ref.taylor_direct` (and therefore also the efficient
    variant) to float tolerance. ``N`` must divide by ``block_n``.
    """
    n, d = q.shape
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    nb = n // block_n

    qn = ref.normalize_rows(q, tau)
    kn = ref.normalize_rows(k, 1.0)
    out_scale = float((n / d) ** 0.5)

    return pl.pallas_call(
        functools.partial(_direct_kernel, out_scale=out_scale),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=True,
    )(qn, kn, v)

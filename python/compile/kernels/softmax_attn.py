"""Softmax attention baseline as a Pallas kernel (flash-style).

Grid over row-blocks with an *online-softmax* column loop: running
row-max and normalizer are updated block by block, so no N x N matrix
is materialized. This is the IO-aware schedule of FlashAttention,
included so the baseline is tiled at the same level of care as the
TaylorShift kernels (paper App. C.3 compares algorithms at equal
implementation level — we keep that parity).

``interpret=True`` — see ``tsa_efficient.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["softmax_attention_pallas"]


def _flash_kernel(q_ref, k_ref, v_ref, y_ref, *, block_k: int, scale: float):
    bn, d = q_ref.shape
    n = k_ref.shape[0]
    q = q_ref[...] * scale
    nkb = n // block_k

    def body(j, carry):
        acc, m, l = carry  # acc: (bn, d), m/l: (bn, 1)
        k_blk = jax.lax.dynamic_slice(k_ref[...], (j * block_k, 0), (block_k, d))
        v_blk = jax.lax.dynamic_slice(v_ref[...], (j * block_k, 0), (block_k, d))
        s = q @ k_blk.T  # (bn, bk)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + p @ v_blk
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bn, d), dtype=q.dtype)
    m0 = jnp.full((bn, 1), -jnp.inf, dtype=q.dtype)
    l0 = jnp.zeros((bn, 1), dtype=q.dtype)
    acc, _, l = jax.lax.fori_loop(0, nkb, body, (acc0, m0, l0))
    y_ref[...] = acc / l


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def softmax_attention_pallas(q, k, v, *, block_n: int = 128, block_k: int = 128):
    """softmax(QK^T/sqrt(d)) V, flash-tiled. N must divide both blocks."""
    n, d = q.shape
    assert n % block_n == 0 and n % block_k == 0
    nb = n // block_n
    scale = float(d**-0.5)

    return pl.pallas_call(
        functools.partial(_flash_kernel, block_k=block_k, scale=scale),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=True,
    )(q, k, v)

"""L2: the TaylorShift transformer encoder in JAX.

A ViT/LRA-style encoder for sequence classification, mirroring the
paper's experimental models (Appendix C): token embedding (linear table
or the App. D.5 3-layer CNN), cosine or learned positional encoding,
``depth`` pre-norm blocks of multi-head self-attention + MLP, mean
pooling, and a linear classifier head.

The attention mechanism is switchable per config — ``softmax``,
``direct`` or ``efficient`` TaylorShift (interchangeable, Section 3) —
including the Table 4 normalization-ablation stages and an optional
Pallas-kernel execution path (``use_pallas``) that routes the per-head
computation through ``kernels/tsa_*.py`` so the paper's L1 kernels lower
into the same HLO.

Everything here runs ONCE at build time (``make artifacts``); the rust
coordinator only ever sees the lowered HLO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.softmax_attn import softmax_attention_pallas
from .kernels.tsa_direct import taylor_direct_pallas
from .kernels.tsa_efficient import taylor_efficient_pallas

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of one encoder (cf. paper Table 6)."""

    name: str
    vocab_size: int
    num_classes: int
    seq_len: int
    depth: int
    d_embed: int
    heads: int
    mlp_ratio: float = 2.0
    variant: str = "efficient"  # softmax | direct | efficient
    norm_stage: str = "full"  # plain | input | full   (Table 4)
    embed: str = "linear"  # linear | conv           (Table 8)
    pos: str = "cosine"  # cosine | learned
    use_pallas: bool = False

    def __post_init__(self):
        assert self.d_embed % self.heads == 0, "heads must divide d_embed"
        assert self.variant in ("softmax", "direct", "efficient")
        assert self.norm_stage in ("plain", "input", "full")
        assert self.embed in ("linear", "conv")
        assert self.pos in ("cosine", "learned")

    @property
    def head_dim(self) -> int:
        return self.d_embed // self.heads

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    scale = math.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


def init_params(key, cfg: ModelConfig) -> Params:
    """Initialize the parameter pytree (plain nested dicts)."""
    keys = iter(jax.random.split(key, 16 + 8 * cfg.depth))
    e = cfg.d_embed
    params: Params = {
        "tok_embed": jax.random.normal(next(keys), (cfg.vocab_size, e), jnp.float32)
        * 0.02
    }
    if cfg.embed == "conv":
        # App. D.5: 3-layer 1-D CNN over the embedded sequence (kernel 3).
        for i in range(3):
            params[f"conv{i}_w"] = (
                jax.random.normal(next(keys), (3, e, e), jnp.float32)
                * math.sqrt(2.0 / (3 * e))
            )
            params[f"conv{i}_b"] = jnp.zeros((e,), jnp.float32)
    if cfg.pos == "learned":
        params["pos_embed"] = (
            jax.random.normal(next(keys), (cfg.seq_len, e), jnp.float32) * 0.02
        )
    for layer in range(cfg.depth):
        d = cfg.head_dim
        block = {
            "ln1_g": jnp.ones((e,), jnp.float32),
            "ln1_b": jnp.zeros((e,), jnp.float32),
            "wqkv": _dense_init(next(keys), e, 3 * e),
            "bqkv": jnp.zeros((3 * e,), jnp.float32),
            # Per-head attention temperature tau (Section 3.3); init at
            # sqrt(d) so initial score range matches softmax attention's
            # post-1/sqrt(d) logits.
            "tau": jnp.full((cfg.heads,), math.sqrt(d), jnp.float32),
            "wo": _dense_init(next(keys), e, e),
            "bo": jnp.zeros((e,), jnp.float32),
            "ln2_g": jnp.ones((e,), jnp.float32),
            "ln2_b": jnp.zeros((e,), jnp.float32),
            "w1": _dense_init(next(keys), e, int(e * cfg.mlp_ratio)),
            "b1": jnp.zeros((int(e * cfg.mlp_ratio),), jnp.float32),
            "w2": _dense_init(next(keys), int(e * cfg.mlp_ratio), e),
            "b2": jnp.zeros((e,), jnp.float32),
        }
        params[f"block{layer}"] = block
    params["ln_f_g"] = jnp.ones((e,), jnp.float32)
    params["ln_f_b"] = jnp.zeros((e,), jnp.float32)
    params["head_w"] = _dense_init(next(keys), e, cfg.num_classes)
    params["head_b"] = jnp.zeros((cfg.num_classes,), jnp.float32)
    return params


def num_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _cosine_pos(seq_len: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len)[:, None].astype(jnp.float32)
    idx = jnp.arange(dim)[None, :]
    angle = pos / jnp.power(10000.0, (2 * (idx // 2)) / dim)
    return jnp.where(idx % 2 == 0, jnp.sin(angle), jnp.cos(angle))


def _block_n(n: int) -> int:
    """Largest power-of-two Pallas block <= 128 that divides n."""
    bn = 128
    while bn > 1 and n % bn != 0:
        bn //= 2
    return bn


def _attention_head(cfg: ModelConfig, q, k, v, tau):
    """Dispatch one head (N, d) through the configured mechanism."""
    n, d = q.shape
    if cfg.variant == "softmax":
        if cfg.use_pallas:
            return softmax_attention_pallas(
                q, k, v, block_n=_block_n(n), block_k=_block_n(n)
            )
        return ref.softmax_attention(q, k, v)
    if cfg.variant == "direct":
        if cfg.norm_stage == "plain":
            return ref.taylor_direct_plain(q, k, v)
        if cfg.norm_stage == "input":
            return ref.taylor_direct_plain(
                ref.normalize_rows(q, tau), ref.normalize_rows(k, 1.0), v
            )
        if cfg.use_pallas:
            return taylor_direct_pallas(q, k, v, tau, block_n=_block_n(n))
        return ref.taylor_direct(q, k, v, tau)
    # efficient
    if cfg.norm_stage == "plain":
        return ref.taylor_efficient_unnormalized(q, k, v)
    if cfg.norm_stage == "input":
        # Input normalization without the output-size rescale: same as
        # Algorithm 1 but the output keeps T-SM scale (divide away the
        # sqrt(N/d) the denominator pre-scale would introduce).
        return ref.taylor_efficient(q, k, v, tau) * (d / n) ** 0.5
    if cfg.use_pallas:
        return taylor_efficient_pallas(q, k, v, tau, block_n=_block_n(n))
    return ref.taylor_efficient(q, k, v, tau)


def _mhsa(cfg: ModelConfig, block: Params, x):
    """Multi-head self-attention over x: (N, E) -> (N, E)."""
    n, e = x.shape
    h, d = cfg.heads, cfg.head_dim
    qkv = x @ block["wqkv"] + block["bqkv"]  # (N, 3E)
    qkv = qkv.reshape(n, 3, h, d).transpose(1, 2, 0, 3)  # (3, h, N, d)
    q, k, v = qkv[0], qkv[1], qkv[2]
    run = lambda qh, kh, vh, tau: _attention_head(cfg, qh, kh, vh, tau)
    y = jax.vmap(run)(q, k, v, block["tau"])  # (h, N, d)
    y = y.transpose(1, 0, 2).reshape(n, e)
    return y @ block["wo"] + block["bo"]


def _conv1d(x, w, b):
    """Same-padded 1-D conv over (N, E) with kernel (3, E, E)."""
    out = jax.lax.conv_general_dilated(
        x[None, :, :],
        w,
        window_strides=(1,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )[0]
    return out + b


def forward_single(cfg: ModelConfig, params: Params, tokens) -> jnp.ndarray:
    """Logits for one sequence of token ids (N,) -> (num_classes,)."""
    x = params["tok_embed"][tokens]  # (N, E)
    if cfg.embed == "conv":
        for i in range(3):
            x = jax.nn.gelu(_conv1d(x, params[f"conv{i}_w"], params[f"conv{i}_b"]))
    if cfg.pos == "learned":
        x = x + params["pos_embed"]
    else:
        x = x + _cosine_pos(cfg.seq_len, cfg.d_embed)
    for layer in range(cfg.depth):
        block = params[f"block{layer}"]
        x = x + _mhsa(cfg, block, _layer_norm(x, block["ln1_g"], block["ln1_b"]))
        hmid = jax.nn.gelu(
            _layer_norm(x, block["ln2_g"], block["ln2_b"]) @ block["w1"] + block["b1"]
        )
        x = x + hmid @ block["w2"] + block["b2"]
    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    pooled = jnp.mean(x, axis=0)
    return pooled @ params["head_w"] + params["head_b"]


def forward(cfg: ModelConfig, params: Params, tokens) -> jnp.ndarray:
    """Batched logits: tokens (B, N) int32 -> (B, num_classes)."""
    return jax.vmap(lambda t: forward_single(cfg, params, t))(tokens)


def qk_scores_single(cfg: ModelConfig, params: Params, tokens, layer: int = 0):
    """The QK^T score matrix of one layer/head for the Fig. 7 study
    (distribution of attention logits in a trained model)."""
    x = params["tok_embed"][tokens]
    if cfg.pos == "learned":
        x = x + params["pos_embed"]
    else:
        x = x + _cosine_pos(cfg.seq_len, cfg.d_embed)
    for li in range(layer):
        block = params[f"block{li}"]
        x = x + _mhsa(cfg, block, _layer_norm(x, block["ln1_g"], block["ln1_b"]))
        hmid = jax.nn.gelu(
            _layer_norm(x, block["ln2_g"], block["ln2_b"]) @ block["w1"] + block["b1"]
        )
        x = x + hmid @ block["w2"] + block["b2"]
    block = params[f"block{layer}"]
    xn = _layer_norm(x, block["ln1_g"], block["ln1_b"])
    n, e = xn.shape
    h, d = cfg.heads, cfg.head_dim
    qkv = (xn @ block["wqkv"] + block["bqkv"]).reshape(n, 3, h, d).transpose(1, 2, 0, 3)
    q, k = qkv[0], qkv[1]
    qn = ref.normalize_rows(q, block["tau"][:, None, None])
    kn = ref.normalize_rows(k, 1.0)
    return jnp.einsum("hnd,hmd->hnm", qn, kn)

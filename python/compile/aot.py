"""AOT pipeline: lower L2/L1 computations to HLO text + manifest.

``make artifacts`` runs this once; afterwards the rust binary is fully
self-contained. Interchange is **HLO text** — the published ``xla``
crate links xla_extension 0.5.1 which rejects jax>=0.5 serialized
protos (64-bit instruction ids); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emits into ``artifacts/``:

* ``<name>.hlo.txt``     — one HLO module per artifact
* ``<name>.params.bin``  — flat little-endian f32 initial parameters
                           (train/infer artifacts), in manifest order
* ``manifest.json``      — full IO/param/layout metadata the rust
                           registry consumes

Artifact kinds:

* ``infer``     — ``forward(params, tokens) -> logits``
* ``train``     — ``train_step(params, m, v, step, tokens, labels)
                   -> (params', m', v', loss, acc)``
* ``eval``      — ``eval_step(params, tokens, labels) -> (loss, acc)``
* ``attention`` — single-head ``f(q, k, v) -> y`` microkernels (used
                  for rust-emitter parity tests and kernel benches)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import train as train_lib
from .kernels import ref
from .kernels.softmax_attn import softmax_attention_pallas
from .kernels.tsa_direct import taylor_direct_pallas
from .kernels.tsa_efficient import taylor_efficient_pallas
from .model import ModelConfig
from .train import TrainConfig

# ---------------------------------------------------------------------------
# HLO text lowering (see module docstring for why text, not proto)
# ---------------------------------------------------------------------------


def lowered_to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "s32", "uint8": "u8"}[np.dtype(dt).name]


def _spec(name, arr_spec):
    return {
        "name": name,
        "shape": list(arr_spec.shape),
        "dtype": _dtype_tag(arr_spec.dtype),
    }


# ---------------------------------------------------------------------------
# Model config registry (CPU-scaled; substitutions documented in DESIGN.md)
# ---------------------------------------------------------------------------

# ListOps is the real LRA task (generator implemented in rust and here);
# pixel/textbytes are the synthetic stand-ins for CIFAR-pixel/IMDB-byte.
TASKS = {
    "listops": dict(vocab_size=20, num_classes=10, seq_len=256, depth=2,
                    d_embed=64, heads=4, mlp_ratio=2.0),
    "pixel": dict(vocab_size=256, num_classes=4, seq_len=256, depth=1,
                  d_embed=64, heads=4, mlp_ratio=1.0),
    "textbytes": dict(vocab_size=256, num_classes=2, seq_len=512, depth=2,
                      d_embed=64, heads=4, mlp_ratio=2.0),
}

TRAIN_BATCH = 16
EVAL_BATCH = 32
SERVE_BATCHES = (1, 8)
SERVE_BUCKETS = (128, 256, 512, 1024)

# Optimizer substitution (DESIGN.md §5): the paper trains with fused
# LAMB at batch 256-2048 over 200-300 epochs. At our CPU budget
# (batch 16, a few hundred steps) LAMB's layer-wise trust ratios scale
# updates by ||w||/||update|| ~ 0.02 and stall; AdamW at lr 3e-3
# converges in-budget. LAMB stays implemented (train.py) and tested;
# switch via TrainConfig(optimizer="lamb").
DEFAULT_TC = TrainConfig(optimizer="adamw", lr=3e-3, warmup_steps=20,
                         total_steps=600, weight_decay=1e-3)


def model_cfg(task: str, variant: str, name: str | None = None, **overrides) -> ModelConfig:
    base = dict(TASKS[task])
    base.update(overrides)
    return ModelConfig(name=name or f"{task}_{variant}", variant=variant, **base)


# ---------------------------------------------------------------------------
# Param flattening helpers (order shared with the rust registry)
# ---------------------------------------------------------------------------


def flatten_params(params):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    paths = train_lib._tree_paths(params)
    return leaves, paths, treedef


def write_params_bin(path, leaves):
    with open(path, "wb") as f:
        for leaf in leaves:
            f.write(np.asarray(leaf, dtype=np.float32).tobytes())


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


class Emitter:
    def __init__(self, out_dir: str, quick: bool = False):
        self.out_dir = out_dir
        self.quick = quick
        self.manifest = {"version": 1, "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)

    def _write(self, name: str, hlo_text: str, entry: dict):
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(hlo_text)
        entry["path"] = path
        self.manifest["artifacts"][name] = entry
        print(f"  wrote {name} ({len(hlo_text) / 1e6:.2f} MB hlo)", flush=True)

    def attention(self, variant: str, n: int, d: int, use_pallas: bool = False):
        tag = "pallas_" if use_pallas else ""
        name = f"attn_{tag}{variant}_n{n}_d{d}"
        if use_pallas:
            fn = {
                "direct": lambda q, k, v: taylor_direct_pallas(q, k, v, 1.0),
                "efficient": lambda q, k, v: taylor_efficient_pallas(q, k, v, 1.0),
                "softmax": lambda q, k, v: softmax_attention_pallas(q, k, v),
            }[variant]
        else:
            fn = {
                "direct": lambda q, k, v: ref.taylor_direct(q, k, v, 1.0),
                "efficient": lambda q, k, v: ref.taylor_efficient(q, k, v, 1.0),
                "softmax": ref.softmax_attention,
            }[variant]
        spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
        lowered = jax.jit(fn, keep_unused=True).lower(spec, spec, spec)
        io = [_spec(nm, spec) for nm in ("q", "k", "v")]
        self._write(
            name,
            lowered_to_hlo_text(lowered),
            {
                "kind": "attention",
                "variant": variant,
                "pallas": use_pallas,
                "seq_len": n,
                "head_dim": d,
                "inputs": io,
                "outputs": [_spec("y", spec)],
            },
        )

    def _model_entry(self, cfg: ModelConfig, params):
        leaves, paths, _ = flatten_params(params)
        return leaves, paths, {
            "model": cfg.to_dict(),
            "params": [
                {"name": p, "shape": list(l.shape)} for p, l in zip(paths, leaves)
            ],
            "num_params": int(sum(l.size for l in leaves)),
        }

    def infer(self, cfg: ModelConfig, batch: int, seq_len: int | None = None,
              seed: int = 0):
        n = seq_len or cfg.seq_len
        cfg = ModelConfig(**{**cfg.to_dict(), "seq_len": n})
        # NOTE: init does not depend on variant or seq_len (cosine posenc),
        # so artifacts sharing a seed share identical parameters — the
        # serving engine relies on this to hot-swap direct/efficient.
        params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
        leaves, paths, entry = self._model_entry(cfg, params)
        treedef = jax.tree_util.tree_flatten(params)[1]

        def fn(*args):
            flat_params = args[: len(leaves)]
            tokens = args[len(leaves)]
            p = jax.tree_util.tree_unflatten(treedef, flat_params)
            return model_lib.forward(cfg, p, tokens)

        tok_spec = jax.ShapeDtypeStruct((batch, n), jnp.int32)
        arg_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves] + [tok_spec]
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        name = f"{cfg.name}_infer_b{batch}_n{n}"
        write_params_bin(os.path.join(self.out_dir, f"{name}.params.bin"), leaves)
        entry["params_bin"] = f"{name}.params.bin"
        entry.update(
            kind="infer",
            batch=batch,
            seq_len=n,
            inputs=[{"name": f"param:{p}", "shape": list(l.shape), "dtype": "f32"}
                    for p, l in zip(paths, leaves)]
            + [_spec("tokens", tok_spec)],
            outputs=[{"name": "logits", "shape": [batch, cfg.num_classes], "dtype": "f32"}],
        )
        self._write(name, lowered_to_hlo_text(lowered), entry)
        return params

    def train(self, cfg: ModelConfig, tc: TrainConfig, batch: int, seed: int = 0):
        params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
        leaves, paths, entry = self._model_entry(cfg, params)
        treedef = jax.tree_util.tree_flatten(params)[1]
        np_leaves = len(leaves)

        def fn(*args):
            p = jax.tree_util.tree_unflatten(treedef, args[:np_leaves])
            m = jax.tree_util.tree_unflatten(treedef, args[np_leaves : 2 * np_leaves])
            v = jax.tree_util.tree_unflatten(treedef, args[2 * np_leaves : 3 * np_leaves])
            step, tokens, labels = args[3 * np_leaves :]
            p2, m2, v2, loss, acc = train_lib.train_step(cfg, tc, p, m, v, step, tokens, labels)
            return (
                tuple(jax.tree_util.tree_flatten(p2)[0])
                + tuple(jax.tree_util.tree_flatten(m2)[0])
                + tuple(jax.tree_util.tree_flatten(v2)[0])
                + (loss, acc)
            )

        leaf_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
        step_spec = jax.ShapeDtypeStruct((), jnp.int32)
        tok_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
        lab_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
        arg_specs = leaf_specs * 3 + [step_spec, tok_spec, lab_spec]
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        name = f"{cfg.name}_train_b{batch}"
        write_params_bin(os.path.join(self.out_dir, f"{name}.params.bin"), leaves)
        entry["params_bin"] = f"{name}.params.bin"
        entry.update(
            kind="train",
            batch=batch,
            seq_len=cfg.seq_len,
            train=tc.to_dict(),
            inputs=(
                [{"name": f"param:{p}", "shape": list(l.shape), "dtype": "f32"}
                 for p, l in zip(paths, leaves)]
                + [{"name": f"m:{p}", "shape": list(l.shape), "dtype": "f32"}
                   for p, l in zip(paths, leaves)]
                + [{"name": f"v:{p}", "shape": list(l.shape), "dtype": "f32"}
                   for p, l in zip(paths, leaves)]
                + [
                    {"name": "step", "shape": [], "dtype": "s32"},
                    _spec("tokens", tok_spec),
                    _spec("labels", lab_spec),
                ]
            ),
            outputs=(
                [{"name": f"param:{p}", "shape": list(l.shape), "dtype": "f32"}
                 for p, l in zip(paths, leaves)]
                + [{"name": f"m:{p}", "shape": list(l.shape), "dtype": "f32"}
                   for p, l in zip(paths, leaves)]
                + [{"name": f"v:{p}", "shape": list(l.shape), "dtype": "f32"}
                   for p, l in zip(paths, leaves)]
                + [
                    {"name": "loss", "shape": [], "dtype": "f32"},
                    {"name": "acc", "shape": [], "dtype": "f32"},
                ]
            ),
        )
        self._write(name, lowered_to_hlo_text(lowered), entry)

    def eval(self, cfg: ModelConfig, batch: int, seed: int = 0):
        params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
        leaves, paths, entry = self._model_entry(cfg, params)
        treedef = jax.tree_util.tree_flatten(params)[1]

        def fn(*args):
            p = jax.tree_util.tree_unflatten(treedef, args[: len(leaves)])
            tokens, labels = args[len(leaves) :]
            return train_lib.eval_step(cfg, p, tokens, labels)

        tok_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
        lab_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
        arg_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves] + [
            tok_spec,
            lab_spec,
        ]
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        name = f"{cfg.name}_eval_b{batch}"
        entry.update(
            kind="eval",
            batch=batch,
            seq_len=cfg.seq_len,
            inputs=[{"name": f"param:{p}", "shape": list(l.shape), "dtype": "f32"}
                    for p, l in zip(paths, leaves)]
            + [_spec("tokens", tok_spec), _spec("labels", lab_spec)],
            outputs=[
                {"name": "loss", "shape": [], "dtype": "f32"},
                {"name": "acc", "shape": [], "dtype": "f32"},
            ],
        )
        self._write(name, lowered_to_hlo_text(lowered), entry)

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts")


# ---------------------------------------------------------------------------
# The full artifact set (per-experiment index in DESIGN.md §7)
# ---------------------------------------------------------------------------


def build_all(out_dir: str, quick: bool = False):
    em = Emitter(out_dir, quick=quick)

    print("[1/6] attention microkernels (parity + kernel benches)")
    for variant in ("direct", "efficient", "softmax"):
        em.attention(variant, 256, 16)
        em.attention(variant, 256, 16, use_pallas=True)
        if not quick:
            em.attention(variant, 1024, 64)

    print("[2/6] serving inference artifacts (listops engine)")
    serve_buckets = SERVE_BUCKETS[:2] if quick else SERVE_BUCKETS
    for bucket in serve_buckets:
        for b in SERVE_BATCHES:
            for variant in ("direct", "efficient"):
                cfg = model_cfg("listops", variant, name=f"serve_{variant}")
                em.infer(cfg, batch=b, seq_len=bucket, seed=7)
        # softmax baseline at b=1 for the Fig 3/9 model-level comparison
        cfg = model_cfg("listops", "softmax", name="serve_softmax")
        em.infer(cfg, batch=1, seq_len=bucket, seed=7)

    print("[3/6] Table 3 train/eval artifacts (3 tasks x 3 variants)")
    tasks = ("listops",) if quick else ("listops", "pixel", "textbytes")
    for task in tasks:
        for variant in ("softmax", "direct", "efficient"):
            cfg = model_cfg(task, variant)
            em.train(cfg, DEFAULT_TC, TRAIN_BATCH, seed=1)
            em.eval(cfg, EVAL_BATCH, seed=1)

    if not quick:
        print("[4/6] Table 4 normalization ablation (pixel)")
        for variant in ("direct", "efficient"):
            for stage in ("plain", "input", "full"):
                if (variant, stage) == ("efficient", "plain"):
                    # included — the expected divergence IS the result
                    pass
                cfg = model_cfg("pixel", variant,
                                name=f"pixel_{variant}_{stage}", norm_stage=stage)
                em.train(cfg, DEFAULT_TC, TRAIN_BATCH, seed=2)

        print("[5/6] Table 5 heads ablation (pixel, efficient + direct)")
        for h in (1, 2, 4, 8, 16):
            for variant in ("efficient", "direct"):
                cfg = model_cfg("pixel", variant,
                                name=f"pixel_{variant}_h{h}", heads=h)
                em.train(cfg, DEFAULT_TC, TRAIN_BATCH, seed=3)

        print("[6/6] Table 8 conv-embedding ablation")
        for task in ("listops", "pixel", "textbytes"):
            cfg = model_cfg(task, "efficient",
                            name=f"{task}_efficient_conv", embed="conv")
            em.train(cfg, DEFAULT_TC, TRAIN_BATCH, seed=4)

    em.finish()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true",
                    help="reduced artifact grid (CI smoke)")
    args = ap.parse_args()
    build_all(args.out, quick=args.quick)


if __name__ == "__main__":
    main()

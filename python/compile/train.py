"""Training step: cross-entropy loss + LAMB/AdamW in plain jnp.

The paper trains with *fused LAMB* (Table 6); optax is unavailable in
this environment, so both LAMB (You et al., 2020) and AdamW are
implemented directly on the parameter pytree. The entire train step —
forward, backward, optimizer update and the warmup+cosine lr schedule —
is one jit-able function that ``aot.py`` lowers to a single HLO module;
the rust train driver just feeds batches and round-trips the state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import model as model_lib

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "lamb"  # lamb | adamw
    lr: float = 1e-3
    warmup_steps: int = 50
    total_steps: int = 1000
    weight_decay: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-6
    # LAMB trust-ratio clamp.
    trust_min: float = 0.0
    trust_max: float = 10.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def lr_at(tc: TrainConfig, step) -> jnp.ndarray:
    """Warmup + cosine decay (paper Table 6 schedule), as a jnp expr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - tc.warmup_steps) / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return tc.lr * warm * cos


def init_opt_state(params: Params) -> Tuple[Params, Params]:
    """(m, v) moment trees, zero-initialized."""
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def loss_and_acc(cfg, params, tokens, labels):
    """Mean CE loss + accuracy over a batch."""
    logits = model_lib.forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return jnp.mean(nll), acc


def _is_no_decay(path: str) -> bool:
    """Biases, layernorm gains and tau get no weight decay / trust ratio
    exemption (standard LAMB practice)."""
    leaf = path.split("/")[-1]
    return (
        leaf.startswith("b")
        or leaf.startswith("ln")
        or leaf in ("tau", "pos_embed", "head_b")
        or leaf.endswith("_b")
        or leaf.endswith("_g")
    )


def _tree_paths(tree) -> list:
    paths = []

    def walk(prefix, node):
        if isinstance(node, dict):
            for key in sorted(node):
                walk(f"{prefix}/{key}" if prefix else key, node[key])
        else:
            paths.append(prefix)

    walk("", tree)
    return paths


def _update_leaf(tc: TrainConfig, path, p, g, m, v, lr, t):
    """One optimizer step on a single leaf; returns (p', m', v')."""
    m_new = tc.beta1 * m + (1.0 - tc.beta1) * g
    v_new = tc.beta2 * v + (1.0 - tc.beta2) * g * g
    m_hat = m_new / (1.0 - tc.beta1**t)
    v_hat = v_new / (1.0 - tc.beta2**t)
    update = m_hat / (jnp.sqrt(v_hat) + tc.eps)
    if not _is_no_decay(path):
        update = update + tc.weight_decay * p
    if tc.optimizer == "lamb" and not _is_no_decay(path):
        w_norm = jnp.linalg.norm(p)
        u_norm = jnp.linalg.norm(update)
        trust = jnp.where(
            (w_norm > 0) & (u_norm > 0),
            jnp.clip(w_norm / u_norm, tc.trust_min, tc.trust_max),
            1.0,
        )
        update = trust * update
    return p - lr * update, m_new, v_new


def train_step(cfg, tc: TrainConfig, params, m, v, step, tokens, labels):
    """One optimization step. Pure function of its inputs — the unit the
    AOT pipeline lowers. Returns (params', m', v', loss, acc)."""
    (loss, acc), grads = jax.value_and_grad(
        lambda p: loss_and_acc(cfg, p, tokens, labels), has_aux=True
    )(params)
    lr = lr_at(tc, step)
    t = step.astype(jnp.float32) + 1.0

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(m)[0]
    flat_v = jax.tree_util.tree_flatten(v)[0]
    paths = _tree_paths(params)
    assert len(paths) == len(flat_p), "path walk must match tree_flatten order"

    new_p, new_m, new_v = [], [], []
    for path, p, g, mm, vv in zip(paths, flat_p, flat_g, flat_m, flat_v):
        p2, m2, v2 = _update_leaf(tc, path, p, g, mm, vv, lr, t)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, new_p), unf(treedef, new_m), unf(treedef, new_v), loss, acc


def eval_step(cfg, params, tokens, labels):
    """Loss + accuracy without updates (lowered for the eval path)."""
    return loss_and_acc(cfg, params, tokens, labels)

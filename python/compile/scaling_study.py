"""Fig. 5 / Fig. 6 / Table 1 scaling study (python side).

Measures the mean magnitudes of efficient-TaylorShift's intermediate
expressions with Q, K, V rows uniform on the unit sphere (the paper's
sampling regime, 16384-sample batches in the paper; sample count here
is configurable) and fits log-log slopes against the paper's laws.

Run once at build time if you want the JSON next to the artifacts:

    python -m compile.scaling_study --out ../bench_out/fig5_python.json
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


def unit_rows(key, n, d):
    x = jax.random.normal(key, (n, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def measure(n: int, d: int, reps: int, seed: int = 0):
    out = {"a_mod": 0.0, "y_denom": 0.0, "y": 0.0, "squared_v": 0.0, "linear_v": 0.0}
    for rep in range(reps):
        key = jax.random.PRNGKey(seed * 1000 + rep)
        kq, kk, kv = jax.random.split(key, 3)
        sizes = ref.intermediate_sizes(
            unit_rows(kq, n, d), unit_rows(kk, n, d), unit_rows(kv, n, d)
        )
        out["a_mod"] += sizes["a_mod"]["fro"]
        out["y_denom"] += sizes["y_denom"]["row"]
        out["y"] += sizes["y"]["row"]
        out["squared_v"] += sizes["squared_v"]["fro"]
        out["linear_v"] += sizes["linear_v"]["fro"]
    return {k: v / reps for k, v in out.items()}


def loglog_slope(ns, ys):
    x = np.log(np.asarray(ns, dtype=np.float64))
    y = np.log(np.asarray(ys, dtype=np.float64))
    return float(np.polyfit(x, y, 1)[0])


def run_study(d: int = 16, ns=None, reps: int = 4, seed: int = 0):
    ns = ns or [64, 128, 256, 512, 1024, 2048, 4096]
    rows = []
    for n in ns:
        m = measure(n, d, reps, seed)
        m["n"] = n
        rows.append(m)
    slopes = {
        key: loglog_slope(ns, [r[key] for r in rows])
        for key in ("a_mod", "y_denom", "y")
    }
    # Paper Table 1 exponents in N: A_mod ~ N, Y_denom ~ N, Y ~ N^{-1/2}.
    expected = {"a_mod": 1.0, "y_denom": 1.0, "y": -0.5}
    return {"d": d, "rows": rows, "slopes": slopes, "expected": expected}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--reps", type=int, default=4)
    args = ap.parse_args()
    result = run_study(d=args.d, reps=args.reps)
    print(f"d = {result['d']}")
    print(f"{'N':>6} {'|A_mod|':>12} {'|Y_denom|':>12} {'|Y|':>10}")
    for r in result["rows"]:
        print(f"{r['n']:>6} {r['a_mod']:>12.2f} {r['y_denom']:>12.2f} {r['y']:>10.4f}")
    print("\nlog-log slopes vs paper Table 1:")
    for k, s in result["slopes"].items():
        print(f"  {k:8s}: {s:+.3f}  (paper {result['expected'][k]:+.1f})")
    if args.out:
        import os

        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

"""L2 profiling: op-level statistics of lowered HLO modules.

Parses the HLO text artifacts and reports per-module op histograms,
fusion counts, dot/elementwise ratios and estimated FLOPs — the
evidence base for the EXPERIMENTS.md §Perf L2 iterations (is anything
recomputed? did a change increase fusion? how much of the module is
matmul?).

    python -m compile.hlo_stats --dir ../artifacts --filter serve_
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter

OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},\s]*\s([a-z][\w\-]*)\(")
SHAPE_RE = re.compile(r"f32\[([\d,]*)\]")


def parse_hlo(text: str):
    """Count ops and estimate dot FLOPs from an HLO text module."""
    ops = Counter()
    dot_flops = 0
    for line in text.splitlines():
        m = OP_RE.match(line)
        if not m:
            continue
        op = m.group(1)
        ops[op] += 1
        if op == "dot":
            # crude: product of all dims mentioned on the line's result
            # shape × 2 (the result shape is the first f32[...] token).
            shapes = SHAPE_RE.findall(line)
            if shapes and shapes[0]:
                result = 1
                for dim in shapes[0].split(","):
                    result *= int(dim)
                # contraction dim: approximate with the largest operand dim
                dims = [int(x) for s in shapes[1:] for x in s.split(",") if x]
                k = max(dims) if dims else 1
                dot_flops += 2 * result * k
    return ops, dot_flops


def summarize(name: str, text: str):
    ops, dot_flops = parse_hlo(text)
    total = sum(ops.values())
    fusions = ops.get("fusion", 0)
    dots = ops.get("dot", 0)
    top = ", ".join(f"{op}:{n}" for op, n in ops.most_common(6))
    return {
        "name": name,
        "total_ops": total,
        "fusions": fusions,
        "dots": dots,
        "est_dot_gflops": dot_flops / 1e9,
        "top_ops": top,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="../artifacts")
    ap.add_argument("--filter", default="", help="substring filter on artifact names")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    rows = []
    for fname in sorted(os.listdir(args.dir)):
        if not fname.endswith(".hlo.txt") or args.filter not in fname:
            continue
        with open(os.path.join(args.dir, fname)) as f:
            rows.append(summarize(fname.removesuffix(".hlo.txt"), f.read()))

    if not rows:
        print("no matching artifacts")
        return
    width = max(len(r["name"]) for r in rows)
    print(f"{'artifact':<{width}} {'ops':>6} {'fus':>5} {'dots':>5} {'~dotGF':>8}  top ops")
    for r in rows:
        print(
            f"{r['name']:<{width}} {r['total_ops']:>6} {r['fusions']:>5} "
            f"{r['dots']:>5} {r['est_dot_gflops']:>8.3f}  {r['top_ops']}"
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()

//! Regenerates **Figure 2**: attention-module inference time (top) and
//! memory (bottom) vs sequence length, for softmax attention and both
//! TaylorShift implementations, at several head dimensions — including
//! the empirical speed crossover N̂₀ and the analytical/entry-model
//! memory crossover N̂₁.
//!
//! Timing runs rust-emitted PJRT executables (h=1, like the paper's
//! single-head module benchmark) when a real backend is present; on the
//! offline stub (where `PjRtClient::compile` is gated off, e.g. CI's
//! bench-smoke job) it falls back to the pure-rust reference kernels —
//! the relative shape of the curves is what the figure is about. Memory
//! uses the paper's own entry-count model at fp32, since CPU PJRT
//! exposes no VRAM analogue.
//!
//! Run: `cargo bench --bench fig2_attention`  (TS_BENCH_QUICK=1 to smoke)

use taylorshift::analysis::{memory, transitions};
use taylorshift::attention::{self, selector, AttentionVariant};
use taylorshift::bench_support::{bench, fmt_mib, fmt_seconds, BenchConfig, Table, write_json};
use taylorshift::runtime::emitter::{self, EmitVariant};
use taylorshift::runtime::Runtime;
use taylorshift::tensor::Tensor;
use taylorshift::util::json::Json;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // d=64 pushes the sweep to N≈16k (N²d matmuls get slow on CPU);
    // included only with TS_BENCH_FULL=1.
    let full = std::env::var("TS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let rt = Runtime::cpu().ok();
    let mut host_fallback = false;
    let ds: &[usize] = if quick {
        &[16]
    } else if full {
        &[8, 16, 32, 64]
    } else {
        &[8, 16, 32]
    };
    let mut all_series = Vec::new();

    for &d in ds {
        let n0 = transitions::n0(d as u64);
        let n1 = transitions::n1(d as u64);
        // Log-spaced N from 128 well past the speed crossover: the CPU
        // crossover sits above the analytical N0 (memory-bound efficient
        // path — §5.1's N̂0 > N0 observation), so sweep to ~8×N0.
        let factor = if d >= 32 { 4.0 } else { 8.0 };
        let max_n = if quick { (n0 * 2.0) as usize } else { (n0 * factor) as usize };
        let mut ns = vec![];
        let mut n = 128usize;
        while n <= max_n {
            ns.push(n);
            n = ((n as f64 * 1.45) as usize).div_ceil(32) * 32;
        }
        let cfg = if quick {
            BenchConfig { warmup_iters: 1, min_iters: 2, max_iters: 4, target_seconds: 0.15 }
        } else {
            BenchConfig { warmup_iters: 2, min_iters: 4, max_iters: 30, target_seconds: 0.6 }
        };

        println!("\n=== Fig 2, d = {d} (theory: N0={n0:.0}, N1={n1:.0}) ===\n");
        let mut table = Table::new(&[
            "N", "softmax", "direct", "efficient", "mem softmax/direct", "mem efficient",
        ]);
        let (mut t_dir, mut t_eff) = (Vec::new(), Vec::new());
        for &n in &ns {
            let q = Tensor::randn(&[n, d], 1);
            let k = Tensor::randn(&[n, d], 2);
            let v = Tensor::randn(&[n, d], 3);
            let mut time_of = |variant: EmitVariant| -> f64 {
                if let Some(rt) = &rt {
                    if let Ok(exe) = emitter::compile_attention(rt, variant, n, d, 1.0) {
                        return bench(format!("{variant:?}_n{n}_d{d}"), &cfg, || {
                            emitter::run_attention(&exe, &q, &k, &v).unwrap();
                        })
                        .mean_s;
                    }
                }
                // Stub backend: bench the pure-rust reference kernels.
                host_fallback = true;
                let hv = match variant {
                    EmitVariant::Softmax => AttentionVariant::Softmax,
                    EmitVariant::TaylorDirect => AttentionVariant::Direct,
                    EmitVariant::TaylorEfficient => AttentionVariant::Efficient,
                };
                bench(format!("{variant:?}_n{n}_d{d}"), &cfg, || {
                    std::hint::black_box(attention::run_variant(hv, &q, &k, &v, 1.0));
                })
                .mean_s
            };
            let ts = time_of(EmitVariant::Softmax);
            let td = time_of(EmitVariant::TaylorDirect);
            let te = time_of(EmitVariant::TaylorEfficient);
            t_dir.push(td);
            t_eff.push(te);
            let mem_d = memory::mib(memory::entries_direct(n as u64, d as u64), 4);
            let mem_e = memory::mib(memory::entries_efficient(n as u64, d as u64), 4);
            table.row(&[
                n.to_string(),
                fmt_seconds(ts),
                fmt_seconds(td),
                fmt_seconds(te),
                fmt_mib(mem_d * 1024.0 * 1024.0),
                fmt_mib(mem_e * 1024.0 * 1024.0),
            ]);
            all_series.push(Json::from_pairs(vec![
                ("d", Json::Num(d as f64)),
                ("n", Json::Num(n as f64)),
                ("t_softmax", Json::Num(ts)),
                ("t_direct", Json::Num(td)),
                ("t_efficient", Json::Num(te)),
                ("mem_direct_mib", Json::Num(mem_d)),
                ("mem_efficient_mib", Json::Num(mem_e)),
            ]));
        }
        table.print();
        match selector::calibrate_crossover(&ns, &t_dir, &t_eff) {
            Some(nhat0) => println!(
                "\nempirical N̂0 = {nhat0:.0}   theory N0 = {n0:.0}   Δ = {:+.0}   (paper on A100: Δ ≈ 18d = {})",
                nhat0 - n0,
                18 * d
            ),
            None => println!("\nno empirical speed crossover within sweep (N ≤ {max_n})"),
        }
        println!("memory crossover (entry model): N1 = {n1:.0} — efficient wins beyond this");
    }

    let backend = if host_fallback { "host-reference" } else { "pjrt" };
    write_json(
        "fig2_attention",
        &Json::from_pairs(vec![
            ("backend", Json::Str(backend.to_string())),
            ("series", Json::Arr(all_series)),
        ]),
    );
    println!("\nwrote bench_out/fig2_attention.json (backend: {backend})");
    Ok(())
}

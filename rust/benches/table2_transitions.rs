//! Regenerates **Table 2**: transition points N₀ (FLOP equality, Eq. 7)
//! and N₁ (memory equality, Eq. 9) for typical head dimensions, and
//! numerically verifies each against the raw cost models.
//!
//! Run: `cargo bench --bench table2_transitions`

use taylorshift::analysis::{flops, memory, transitions};
use taylorshift::bench_support::{write_json, Table};
use taylorshift::util::json::Json;

fn main() {
    println!("\n=== Table 2: efficiency transition points ===\n");
    let mut t = Table::new(&[
        "d",
        "N0 (speed)",
        "N1 (memory)",
        "bound d²+d+¾",
        "bound ½d²+2d+½",
        "FLOP check",
        "entry check",
    ]);
    let mut rows = Vec::new();
    for (d, n0, n1) in transitions::table2() {
        // verification: direct is cheaper just below, efficient just above
        let flop_ok = flops::ops_direct(n0 - 2, d) < flops::ops_efficient(n0 - 2, d)
            && flops::ops_direct(n0 + 2, d) > flops::ops_efficient(n0 + 2, d);
        let mem_ok = memory::entries_direct(n1 - 2, d) < memory::entries_efficient(n1 - 2, d)
            && memory::entries_direct(n1 + 2, d) > memory::entries_efficient(n1 + 2, d);
        t.row(&[
            d.to_string(),
            n0.to_string(),
            n1.to_string(),
            format!("{:.0}", transitions::n0_bound(d)),
            format!("{:.0}", transitions::n1_bound(d)),
            if flop_ok { "✓" } else { "✗" }.to_string(),
            if mem_ok { "✓" } else { "✗" }.to_string(),
        ]);
        rows.push(Json::from_pairs(vec![
            ("d", Json::Num(d as f64)),
            ("n0", Json::Num(n0 as f64)),
            ("n1", Json::Num(n1 as f64)),
        ]));
        assert!(flop_ok && mem_ok, "transition verification failed at d={d}");
    }
    t.print();
    println!(
        "\npaper quotes d=128 → N0=16513, N1=8446; we compute N0={}, N1={}",
        transitions::n0(128).round(),
        transitions::n1(128).round()
    );
    println!(
        "d* (FLOP-optimal per-head dim, Sec 4.3) = {:.4} → ĥ0 = d_emb/d* > d_emb",
        transitions::d_star_ops()
    );
    write_json("table2", &Json::Arr(rows));
}

//! Regenerates **Figure 9** (extended Fig. 3): model-level inference
//! time and memory for softmax / direct / efficient — plus the
//! efficient variant at increased head counts (h = 16/32/64 in the
//! paper), showing how the head-count lever makes TaylorShift
//! competitive.
//!
//! Model-level time comes from the AOT serving artifacts; the head
//! sweep reuses the fused MHSA emitter at the model's (N, d_emb) since
//! the AOT grid pins h. Memory uses the MHSA entry model.
//!
//! Run: `cargo bench --bench fig9_models`

use taylorshift::analysis::mhsa;
use taylorshift::bench_support::{bench, fmt_mib, fmt_seconds, BenchConfig, Table, write_json};
use taylorshift::runtime::emitter::{self, EmitVariant};
use taylorshift::runtime::{literal, Registry, Runtime};
use taylorshift::tensor::Tensor;
use taylorshift::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let reg = Registry::open(rt.clone(), &dir)?;
    let quick = std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let buckets: &[usize] = if quick { &[256] } else { &[256, 512, 1024] };
    let (d_emb, depth) = (64u64, 2u64);

    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: if quick { 5 } else { 20 },
        target_seconds: if quick { 0.2 } else { 0.8 },
    };

    println!("\n=== Fig 9: model-level comparison incl. head sweep ===\n");
    let mut table = Table::new(&["N", "model", "time", "attn mem (model)"]);
    let mut series = Vec::new();

    for &n in buckets {
        // Full-model artifacts (h = 4).
        for variant in ["softmax", "direct", "efficient"] {
            let name = format!("serve_{variant}_infer_b1_n{n}");
            if !reg.contains(&name) {
                continue;
            }
            let exe = reg.load(&name)?;
            let params = reg.load_params(&name)?;
            let tokens: Vec<Vec<i32>> = vec![(0..n).map(|i| 1 + (i % 17) as i32).collect()];
            let param_lits: Vec<xla::Literal> = params
                .iter()
                .map(|t| literal::tensor_to_literal(t).unwrap())
                .collect();
            let tokens_lit = literal::tokens_to_literal(&tokens).unwrap();
            let inputs: Vec<&xla::Literal> = param_lits
                .iter()
                .chain(std::iter::once(&tokens_lit))
                .collect();
            let t = bench(format!("{variant}_n{n}"), &cfg, || {
                exe.run(&inputs).unwrap();
            })
            .mean_s;
            let entries = match variant {
                "efficient" => mhsa::entries_efficient_mhsa(n as u64, d_emb, 4),
                _ => mhsa::entries_direct_mhsa(n as u64, d_emb, 4),
            } * depth;
            table.row(&[
                n.to_string(),
                format!("{variant} (h=4, full model)"),
                fmt_seconds(t),
                fmt_mib(entries as f64 * 4.0),
            ]);
            series.push(Json::from_pairs(vec![
                ("n", Json::Num(n as f64)),
                ("model", Json::Str(format!("{variant}_h4"))),
                ("time_s", Json::Num(t)),
            ]));
        }
        // Efficient at higher head counts — MHSA-level (the paper's
        // "TaylorShift becomes very competitive at h=32/64" argument).
        for &h in if quick { &[16usize][..] } else { &[8usize, 16, 32][..] } {
            let d = (d_emb as usize) / h;
            let q = Tensor::randn(&[h, n, d], 1);
            let k = Tensor::randn(&[h, n, d], 2);
            let v = Tensor::randn(&[h, n, d], 3);
            let comp = emitter::build_mhsa(EmitVariant::TaylorEfficient, n, d, h, 1.0)?;
            let exe = rt.compile(&comp)?;
            let ql = literal::tensor_to_literal(&q)?;
            let kl = literal::tensor_to_literal(&k)?;
            let vl = literal::tensor_to_literal(&v)?;
            let t = bench(format!("eff_h{h}_n{n}"), &cfg, || {
                let result = exe.execute::<&xla::Literal>(&[&ql, &kl, &vl]).unwrap();
                let _ = &result[0][0];
            })
            .mean_s;
            let entries = mhsa::entries_efficient_mhsa(n as u64, d_emb, h as u64) * depth;
            table.row(&[
                n.to_string(),
                format!("efficient MHSA h={h}"),
                fmt_seconds(t),
                fmt_mib(entries as f64 * 4.0),
            ]);
            series.push(Json::from_pairs(vec![
                ("n", Json::Num(n as f64)),
                ("model", Json::Str(format!("efficient_mhsa_h{h}"))),
                ("time_s", Json::Num(t)),
            ]));
        }
    }
    table.print();
    println!(
        "\npaper direction: at default h the efficient variant lags other mechanisms at\n\
         short N, but raising h shrinks both time and memory (cubic d³ → (d_emb/h)³),\n\
         making TaylorShift competitive — same ordering expected in the h-sweep rows."
    );
    write_json("fig9_models", &Json::Arr(series));
    Ok(())
}

//! Regenerates **Table 7**: training speed and memory per task for the
//! transformer variants (softmax / direct / efficient TaylorShift).
//!
//! Measures wall-clock per optimization step on the AOT train-step
//! artifacts (the paper reports GPU-hours over the full schedule — we
//! report s/step and scale to the paper's step budget), plus the
//! training-memory entry model (activations × 3 for grads+moments) at
//! fp32.
//!
//! Run: `cargo bench --bench table7_train`

use taylorshift::analysis::mhsa;
use taylorshift::bench_support::{fmt_seconds, Table, write_json};
use taylorshift::data::task_by_name;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::json::Json;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let reg = Registry::open(Runtime::cpu()?, &dir)?;
    let quick = std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let tasks: &[&str] = if quick { &["listops"] } else { &["listops", "pixel", "textbytes"] };
    let variants = ["softmax", "direct", "efficient"];
    let steps = if quick { 3 } else { 10 };

    // Model shapes per task (mirrors python/compile/aot.py TASKS).
    let model_dims = |task: &str| -> (u64, u64, u64, u64) {
        match task {
            "listops" => (2, 64, 4, 256),   // depth, d_emb, h, N
            "pixel" => (1, 64, 4, 256),
            _ => (2, 64, 4, 512),
        }
    };

    println!("\n=== Table 7: training speed & memory (B=16, {steps} timed steps) ===\n");
    let mut table = Table::new(&[
        "Model",
        "task",
        "s/step",
        "rel. speed",
        "train mem (attn entries, MiB@32)",
    ]);
    let mut series = Vec::new();
    for task in tasks {
        let mut baseline = None;
        for variant in variants {
            let name = format!("{task}_{variant}_train_b16");
            if !reg.contains(&name) {
                continue;
            }
            let mut driver = TrainDriver::new(&reg, &name)?;
            let gen = task_by_name(task, driver.seq_len()).unwrap();
            let mut rng = Pcg64::new(9);
            // Warmup one step (first run includes one-time costs).
            let b = taylorshift::data::batch::generate_batch(
                &gen, &mut rng, driver.batch_size(), driver.seq_len(),
            );
            driver.step_on(&b.tokens, &b.labels)?;
            let t0 = std::time::Instant::now();
            for _ in 0..steps {
                let b = taylorshift::data::batch::generate_batch(
                    &gen, &mut rng, driver.batch_size(), driver.seq_len(),
                );
                driver.step_on(&b.tokens, &b.labels)?;
            }
            let per_step = t0.elapsed().as_secs_f64() / steps as f64;
            let rel = match baseline {
                None => {
                    baseline = Some(per_step);
                    1.0
                }
                Some(b) => per_step / b,
            };
            let (depth, d_emb, h, n) = model_dims(task);
            // fwd+bwd keeps ~2× activation entries + attention peaks.
            let entries = match variant {
                "efficient" => mhsa::entries_efficient_mhsa(n, d_emb, h),
                _ => mhsa::entries_direct_mhsa(n, d_emb, h),
            } * depth * 16 /* batch */ * 2 /* fwd+bwd */;
            let mem_mib = entries as f64 * 4.0 / (1024.0 * 1024.0);
            table.row(&[
                variant.to_string(),
                task.to_string(),
                fmt_seconds(per_step),
                format!("{rel:.2}x"),
                format!("{mem_mib:.0}"),
            ]);
            series.push(Json::from_pairs(vec![
                ("task", Json::Str(task.to_string())),
                ("variant", Json::Str(variant.to_string())),
                ("s_per_step", Json::Num(per_step)),
                ("mem_mib", Json::Num(mem_mib)),
            ]));
        }
    }
    table.print();
    println!(
        "\npaper Table 7 (A100-hours at N≤4000): direct/efficient TaylorShift cost more than\n\
         softmax at SHORT N (their training lengths sit below the crossover) — the same\n\
         ordering should appear here at N=256/512; the efficient variant pulls ahead only\n\
         past N0(d). Memory: efficient ≪ direct at every setting (entry model)."
    );
    write_json("table7_train", &Json::Arr(series));
    Ok(())
}

//! Regenerates **Table 1 / Figure 5 / Figure 6**: mean magnitudes of
//! the efficient pipeline's intermediate expressions vs N, with fits
//! against the paper's candidate scaling laws.
//!
//! Q, K, V rows are sampled uniformly from the unit sphere (the paper's
//! regime). We report our measured norms, the paper's fitted law, and
//! the relative error of a *rescaled* law (shape match) — Fig. 6 shows
//! the paper's own fits err <1% only asymptotically.
//!
//! Run: `cargo bench --bench fig5_scaling`

use taylorshift::attention::efficient;
use taylorshift::bench_support::{write_json, Table};
use taylorshift::tensor::Tensor;
use taylorshift::util::json::Json;
use taylorshift::util::stats;

fn main() {
    let quick = std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let d = 16usize;
    let ns: Vec<usize> = if quick {
        vec![64, 256, 1024]
    } else {
        vec![64, 128, 256, 512, 1024, 2048, 4096, 8192]
    };
    let reps = if quick { 2 } else { 6 };

    println!("\n=== Fig 5: intermediate-expression magnitudes vs N (d = {d}) ===\n");
    let mut table = Table::new(&[
        "N",
        "|A_mod|",
        "paper (N+1)/√d",
        "|Y_denom|",
        "paper N(d+2)/2d",
        "|Y|",
        "paper √(d/N)",
    ]);
    let mut logn = Vec::new();
    let (mut log_amod, mut log_denom, mut log_y) = (Vec::new(), Vec::new(), Vec::new());
    let mut series = Vec::new();
    for &n in &ns {
        let (mut am, mut dn, mut yy) = (0.0, 0.0, 0.0);
        for rep in 0..reps {
            let q = Tensor::rand_unit_rows(n, d, 100 + rep as u64);
            let k = Tensor::rand_unit_rows(n, d, 200 + rep as u64);
            let v = Tensor::rand_unit_rows(n, d, 300 + rep as u64);
            let (a_mod, _, _, y_denom, y) = efficient::intermediate_sizes(&q, &k, &v);
            am += a_mod;
            dn += y_denom;
            yy += y;
        }
        let (am, dn, yy) = (am / reps as f64, dn / reps as f64, yy / reps as f64);
        let paper_amod = (n as f64 + 1.0) / (d as f64).sqrt();
        let paper_denom = n as f64 * (d as f64 + 2.0) / (2.0 * d as f64);
        let paper_y = (d as f64 / n as f64).sqrt();
        table.row(&[
            n.to_string(),
            format!("{am:.2}"),
            format!("{paper_amod:.2}"),
            format!("{dn:.2}"),
            format!("{paper_denom:.2}"),
            format!("{yy:.4}"),
            format!("{paper_y:.4}"),
        ]);
        logn.push((n as f64).ln());
        log_amod.push(am.ln());
        log_denom.push(dn.ln());
        log_y.push(yy.ln());
        series.push(Json::from_pairs(vec![
            ("n", Json::Num(n as f64)),
            ("a_mod", Json::Num(am)),
            ("y_denom", Json::Num(dn)),
            ("y", Json::Num(yy)),
        ]));
    }
    table.print();

    // Fit log-log slopes: Table 1 predicts exponents +1, +1, -1/2.
    let (_, slope_amod) = stats::linear_fit(&logn, &log_amod);
    let (_, slope_denom) = stats::linear_fit(&logn, &log_denom);
    let (_, slope_y) = stats::linear_fit(&logn, &log_y);
    println!("\nfitted N-exponents (paper Table 1 in parentheses):");
    println!("  A_mod   : {slope_amod:+.3}  (+1)");
    println!("  Y_denom : {slope_denom:+.3}  (+1)");
    println!("  Y       : {slope_y:+.3}  (-0.5)");
    assert!((slope_amod - 1.0).abs() < 0.2, "A_mod exponent off");
    assert!((slope_denom - 1.0).abs() < 0.2, "Y_denom exponent off");
    assert!((slope_y + 0.5).abs() < 0.25, "Y exponent off");
    println!("\n(growth exponents match Table 1 — the un-normalized pipeline diverges with N,\n which is exactly what the Section 3.3 normalization counteracts)");
    write_json("fig5_scaling", &Json::Arr(series));
}

//! Regenerates **Figure 3**: memory and inference time of a FULL
//! transformer encoder with efficient-/direct-TaylorShift (and the
//! softmax baseline when its artifacts exist) vs sequence length.
//!
//! Executes the AOT serving artifacts (whole-model forward, B=1) at
//! each length bucket; model memory is accounted with the paper's
//! MHSA entry model × depth plus activation terms at fp32.
//!
//! Run: `cargo bench --bench fig3_transformer`

use taylorshift::analysis::mhsa;
use taylorshift::bench_support::{bench, fmt_mib, fmt_seconds, BenchConfig, Table, write_json};
use taylorshift::runtime::{literal, Registry, Runtime};
use taylorshift::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let reg = Registry::open(Runtime::cpu()?, &dir)?;
    let quick = std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let buckets: &[usize] = if quick { &[128, 256] } else { &[128, 256, 512, 1024] };
    // serve model: listops cfg — depth 2, d_emb 64, h 4 (d=16).
    let (depth, d_emb, h) = (2u64, 64u64, 4u64);

    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 4,
        max_iters: if quick { 6 } else { 30 },
        target_seconds: if quick { 0.2 } else { 0.8 },
    };

    println!("\n=== Fig 3: full-transformer time & memory vs N (depth {depth}, d_emb {d_emb}, h {h}) ===\n");
    let mut table = Table::new(&[
        "N",
        "t direct",
        "t efficient",
        "mem direct (attn, model-level)",
        "mem efficient",
        "ratio",
    ]);
    let mut series = Vec::new();
    for &n in buckets {
        let mut time_variant = |variant: &str| -> anyhow::Result<f64> {
            let name = format!("serve_{variant}_infer_b1_n{n}");
            let exe = reg.load(&name)?;
            let params = reg.load_params(&name)?;
            let tokens: Vec<Vec<i32>> = vec![(0..n).map(|i| 1 + (i % 17) as i32).collect()];
            let param_lits: Vec<xla::Literal> = params
                .iter()
                .map(|t| literal::tensor_to_literal(t).unwrap())
                .collect();
            let tokens_lit = literal::tokens_to_literal(&tokens).unwrap();
            let inputs: Vec<&xla::Literal> = param_lits
                .iter()
                .chain(std::iter::once(&tokens_lit))
                .collect();
            Ok(bench(format!("{variant}_n{n}"), &cfg, || {
                exe.run(&inputs).unwrap();
            })
            .mean_s)
        };
        let td = time_variant("direct")?;
        let te = time_variant("efficient")?;
        // Model-level attention memory: depth × MHSA entries @ fp32.
        let mem_d = depth as f64 * mhsa::entries_direct_mhsa(n as u64, d_emb, h) as f64 * 4.0;
        let mem_e = depth as f64 * mhsa::entries_efficient_mhsa(n as u64, d_emb, h) as f64 * 4.0;
        table.row(&[
            n.to_string(),
            fmt_seconds(td),
            fmt_seconds(te),
            fmt_mib(mem_d),
            fmt_mib(mem_e),
            format!("{:.2}x", mem_d / mem_e),
        ]);
        series.push(Json::from_pairs(vec![
            ("n", Json::Num(n as f64)),
            ("t_direct", Json::Num(td)),
            ("t_efficient", Json::Num(te)),
            ("mem_direct_bytes", Json::Num(mem_d)),
            ("mem_efficient_bytes", Json::Num(mem_e)),
        ]));
    }
    table.print();
    println!(
        "\npaper (d=32/16 heads, A100): efficient wins memory from ~900 tokens, speed from ~1800;\n\
         expected shape here: efficient memory ratio grows with N, speed crossover near/above 1024 (d=16 → N0≈271 per head,\n\
         but whole-model overheads shift it upward — see EXPERIMENTS.md)."
    );
    write_json("fig3_transformer", &Json::Arr(series));
    Ok(())
}

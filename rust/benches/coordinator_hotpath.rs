//! L3 hot-path microbench (EXPERIMENTS.md §Perf): coordinator overhead
//! must be negligible against executable runtime.
//!
//! Measures, with a zero-cost mock executor:
//!   1. single-request end-to-end latency through router → batcher →
//!      engine thread → response channel (pure coordination overhead);
//!   2. batched throughput at max_batch=8;
//!   3. raw batcher push/flush cost.
//!
//! Run: `cargo bench --bench coordinator_hotpath`

use std::time::{Duration, Instant};
use taylorshift::bench_support::{bench, fmt_seconds, BenchConfig, Table, write_json};
use taylorshift::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use taylorshift::coordinator::engine::{BatchExecutor, Engine, EngineConfig};
use taylorshift::coordinator::request::InferRequest;
use taylorshift::coordinator::router::Route;
use taylorshift::util::json::Json;

struct NullExecutor {
    sizes: Vec<usize>,
}

impl BatchExecutor for NullExecutor {
    fn execute(&mut self, _route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(tokens.iter().map(|_| vec![0.0; 10]).collect())
    }
    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let mut table = Table::new(&["path", "per-op", "ops/s"]);
    let mut series = Vec::new();

    // 1. end-to-end single request, zero batching delay.
    let engine = Engine::start_with(
        EngineConfig::builder()
            .policy(BatchPolicy {
                max_batch: 1,
                max_delay: Duration::ZERO,
            })
            .build()
            .expect("valid engine config"),
        || Ok(NullExecutor { sizes: vec![1, 8] }),
    )
    .unwrap();
    let tokens: Vec<i32> = (0..100).collect();
    let t = bench("e2e_single", &cfg, || {
        engine.infer(tokens.clone()).unwrap();
    });
    table.row(&[
        "engine e2e (single, no delay)".into(),
        fmt_seconds(t.mean_s),
        format!("{:.0}", 1.0 / t.mean_s),
    ]);
    series.push(Json::from_pairs(vec![
        ("path", Json::Str("e2e_single".into())),
        ("mean_s", Json::Num(t.mean_s)),
    ]));
    drop(engine);

    // 2. batched: 8 concurrent submitters per iteration.
    let engine = Engine::start_with(
        EngineConfig::builder()
            .policy(BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(5),
            })
            .build()
            .expect("valid engine config"),
        || Ok(NullExecutor { sizes: vec![1, 8] }),
    )
    .unwrap();
    let t = bench("e2e_batch8", &cfg, || {
        let rxs: Vec<_> = (0..8)
            .map(|_| engine.submit(tokens.clone()).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    });
    table.row(&[
        "engine e2e (8-request fused batch)".into(),
        fmt_seconds(t.mean_s / 8.0),
        format!("{:.0}", 8.0 / t.mean_s),
    ]);
    series.push(Json::from_pairs(vec![
        ("path", Json::Str("e2e_batch8_per_req".into())),
        ("mean_s", Json::Num(t.mean_s / 8.0)),
    ]));
    drop(engine);

    // 3. raw batcher data structure.
    let mut batcher = DynamicBatcher::new(BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(1),
    });
    let route = Route {
        bucket: 128,
        variant: taylorshift::attention::AttentionVariant::Direct,
    };
    let mut id = 0u64;
    let t = bench("batcher_push", &cfg, || {
        let now = Instant::now();
        for _ in 0..64 {
            id += 1;
            let ready = batcher.push(route, InferRequest::new(id, vec![1; 8]), id, now);
            std::hint::black_box(&ready);
        }
        batcher.flush_all();
    });
    table.row(&[
        "batcher push+flush".into(),
        fmt_seconds(t.mean_s / 64.0),
        format!("{:.0}", 64.0 / t.mean_s),
    ]);
    series.push(Json::from_pairs(vec![
        ("path", Json::Str("batcher_push".into())),
        ("mean_s", Json::Num(t.mean_s / 64.0)),
    ]));

    println!("\n=== L3 coordinator hot path ===\n");
    table.print();
    println!(
        "\ntarget: per-request coordination cost ≪ smallest executable time\n\
         (serve_direct_infer_b1_n128 ≈ 1 ms on this CPU — see fig3 bench)."
    );
    write_json("coordinator_hotpath", &Json::Arr(series));
}

//! Streaming decode: per-token latency vs prefix length.
//!
//! The claim under test is the decode-time version of the paper's
//! complexity shift: the KV-cache branch pays O(N·d) per token (it
//! re-attends over the whole prefix), while the recurrent branch pays
//! O(d³) — *independent of N*. The bench sweeps prefix lengths from 256
//! to 8192 and verifies the recurrent per-token time stays flat
//! (≤1.5× from the shortest to the longest prefix) while KV grows.
//! A second sweep streams a whole multi-layer model (attention + MLP
//! per block) with every layer recurrent and reports the same flatness
//! ratio end-to-end.
//!
//! The emitted `bench_out/decode_stream.json` carries
//! `recurrent_flat_ratio`, which CI's bench-smoke job gates against
//! `bench/baseline.json` (see `examples/bench_gate.rs`).
//!
//! Run: `cargo bench --bench decode_stream`  (TS_BENCH_QUICK=1 to smoke)

use std::time::Instant;
use taylorshift::bench_support::{bench, fmt_seconds, write_json, BenchConfig, Table};
use taylorshift::decode::{DecodeConfig, KvCache, RecurrentState};
use taylorshift::model::{ModelConfig, ModelSession, StreamingModel};
use taylorshift::tensor::Tensor;
use taylorshift::util::json::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (d, tau) = (16usize, 1.0f32);
    let lengths: &[usize] = if quick {
        &[256, 1024]
    } else {
        &[256, 512, 1024, 2048, 4096, 8192]
    };

    let mut table = Table::new(&["prefix N", "kv per-token", "recurrent per-token", "kv/rec"]);
    let mut series = Vec::new();
    let mut rec_means = Vec::new();

    for &n in lengths {
        // Build both branches' state over an n-token prefix.
        let k = Tensor::randn(&[n, d], 1);
        let v = Tensor::randn(&[n, d], 2);
        let mut kv = KvCache::new(d, tau);
        let mut rec = RecurrentState::new(d, tau);
        for t in 0..n {
            kv.append(k.row(t), v.row(t));
            rec.append(k.row(t), v.row(t));
        }
        let q = Tensor::randn(&[1, d], 3);

        // KV is timed query-only at the fixed prefix (appending inside
        // the loop would grow the cache and drift the measurement; the
        // O(d) append is negligible against the O(N·d) query anyway).
        let t_kv = bench(format!("kv_n{n}"), &cfg, || {
            std::hint::black_box(kv.query(q.row(0)));
        });
        // Recurrent state is length-independent, so the full step
        // (append + query) is timed; growth across iterations is free.
        let kq = Tensor::randn(&[1, d], 4);
        let kv_tok = Tensor::randn(&[1, d], 5);
        let t_rec = bench(format!("recurrent_n{n}"), &cfg, || {
            std::hint::black_box(rec.decode_step(q.row(0), kq.row(0), kv_tok.row(0)));
        });

        table.row(&[
            format!("{n}"),
            fmt_seconds(t_kv.mean_s),
            fmt_seconds(t_rec.mean_s),
            format!("{:.2}", t_kv.mean_s / t_rec.mean_s),
        ]);
        rec_means.push(t_rec.mean_s);
        series.push(Json::from_pairs(vec![
            ("n", Json::Num(n as f64)),
            ("kv_mean_s", Json::Num(t_kv.mean_s)),
            ("recurrent_mean_s", Json::Num(t_rec.mean_s)),
        ]));
    }

    table.print();

    // One-time promotion cost (the O(N) state build at the crossover).
    let n = if quick { 1024 } else { 4096 };
    let k = Tensor::randn(&[n, d], 6);
    let v = Tensor::randn(&[n, d], 7);
    let mut session = taylorshift::decode::DecodeSession::new(1, d, tau, false);
    for t in 0..n {
        let row = |src: &Tensor, t: usize| Tensor::new(&[1, d], src.row(t).to_vec());
        session.step(&row(&k, t), &row(&k, t), &row(&v, t), None);
    }
    let t0 = Instant::now();
    let promoted = session.promote();
    println!(
        "\none-time KV→recurrent promotion at N={n}: {} (promoted={promoted})",
        fmt_seconds(t0.elapsed().as_secs_f64())
    );

    let flat_ratio = rec_means.last().unwrap() / rec_means.first().unwrap();
    println!(
        "recurrent per-token flatness N={}→N={}: {:.2}x (target ≤1.5x)",
        lengths.first().unwrap(),
        lengths.last().unwrap(),
        flat_ratio
    );

    // Whole-model streaming: one token through every block (pre-LN,
    // multi-head TaylorShift attention, MLP, residuals) with all layers
    // on the recurrent branch. Per-token cost must stay flat in N too —
    // the per-layer states are the only thing that grows with prefix.
    let model_lengths: &[usize] = if quick { &[256, 1024] } else { &[256, 1024, 4096] };
    let model = StreamingModel::new(ModelConfig::from_decode(
        &DecodeConfig {
            heads: 4,
            n_layers: 2,
            ..DecodeConfig::default()
        },
        16,
    ));
    let dm = model.d_model();
    let n_layers = model.config().n_layers;
    let mut model_table = Table::new(&["prefix N", "model per-token"]);
    let mut model_series = Vec::new();
    let mut model_means = Vec::new();
    for &n in model_lengths {
        let mut session =
            ModelSession::with_thresholds(&model, &vec![true; n_layers], vec![None; n_layers]);
        let x = Tensor::randn(&[n, dm], 8);
        for t in 0..n {
            let token = Tensor::new(&[1, dm], x.row(t).to_vec());
            model.step(&mut session, &token);
        }
        let token = Tensor::randn(&[1, dm], 9);
        let t_model = bench(format!("model_n{n}"), &cfg, || {
            std::hint::black_box(model.step(&mut session, &token));
        });
        model_table.row(&[format!("{n}"), fmt_seconds(t_model.mean_s)]);
        model_means.push(t_model.mean_s);
        model_series.push(Json::from_pairs(vec![
            ("n", Json::Num(n as f64)),
            ("model_mean_s", Json::Num(t_model.mean_s)),
        ]));
    }
    model_table.print();
    let model_flat_ratio = model_means.last().unwrap() / model_means.first().unwrap();
    println!(
        "whole-model per-token flatness N={}→N={}: {:.2}x",
        model_lengths.first().unwrap(),
        model_lengths.last().unwrap(),
        model_flat_ratio
    );

    write_json(
        "decode_stream",
        &Json::from_pairs(vec![
            ("series", Json::Arr(series)),
            ("recurrent_flat_ratio", Json::Num(flat_ratio)),
            ("model_series", Json::Arr(model_series)),
            ("model_flat_ratio", Json::Num(model_flat_ratio)),
        ]),
    );
}

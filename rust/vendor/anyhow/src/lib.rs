//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates.io access, so the crate
//! is vendored with exactly the surface this repository uses: a
//! string-backed [`Error`], the [`Result`] alias, the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Error causes are
//! flattened into the message chain (`"context: cause"`), which is all
//! the binaries and tests here rely on.

use std::convert::Infallible;
use std::fmt::{self, Display};

/// String-backed error type. Wrapping is eager: the source error is
/// formatted into the message at construction time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: Display>(msg: M) -> Self {
        Self {
            msg: msg.to_string(),
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`. (`Error` itself deliberately does
// not implement `std::error::Error`, exactly like the real anyhow —
// that is what keeps this blanket impl coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human context to failures, mirroring anyhow's `Context`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        let parsed: std::result::Result<u32, _> = "x".parse::<u32>();
        let v = parsed.context("parsing x")?;
        Ok(v)
    }

    #[test]
    fn context_chains_messages() {
        let err = fails().unwrap_err();
        assert!(err.to_string().starts_with("parsing x: "));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        fn guard(x: u32) -> Result<u32> {
            ensure!(x > 2, "too small: {x}");
            Ok(x)
        }
        assert!(guard(1).is_err());
        assert_eq!(guard(3).unwrap(), 3);
    }
}

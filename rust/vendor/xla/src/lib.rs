//! API-compatible stub of the `xla` PJRT bindings for offline builds.
//!
//! The container has no crates.io access and no libxla, so the crate
//! is vendored with the exact surface this repository calls. Host-side
//! pieces ([`Literal`], shapes, graph construction via [`XlaBuilder`])
//! are fully functional; everything that would need a real backend is
//! funneled through one gate: [`PjRtClient::compile`] returns an error.
//! [`PjRtLoadedExecutable`] and [`PjRtBuffer`] are uninhabited, so code
//! paths "after compile" type-check but are statically unreachable.
//!
//! Swapping this path dependency for the real `xla` crate restores
//! execution without source changes.

use std::borrow::Borrow;
use std::fmt::{self, Display};

/// Stub error type (string-backed, like an XLA status).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types used by this repository's emitted graphs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> ArrayData;
    fn unwrap(data: &ArrayData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> ArrayData {
        ArrayData::F32(data)
    }
    fn unwrap(data: &ArrayData) -> Option<&[Self]> {
        match data {
            ArrayData::F32(v) => Some(v),
            ArrayData::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> ArrayData {
        ArrayData::I32(data)
    }
    fn unwrap(data: &ArrayData) -> Option<&[Self]> {
        match data {
            ArrayData::I32(v) => Some(v),
            ArrayData::F32(_) => None,
        }
    }
}

/// Typed storage behind an array literal.
#[derive(Clone, Debug)]
pub enum ArrayData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl ArrayData {
    fn len(&self) -> usize {
        match self {
            ArrayData::F32(v) => v.len(),
            ArrayData::I32(v) => v.len(),
        }
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal: a typed array with a shape, or a tuple of literals.
#[derive(Clone, Debug)]
pub enum Literal {
    Array { dims: Vec<i64>, data: ArrayData },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::Array {
            dims: vec![],
            data: T::wrap(vec![v]),
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let count: i64 = dims.iter().product();
                if count as usize != data.len() {
                    return Err(Error::new(format!(
                        "reshape: {} elements into dims {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::Array {
                    dims: dims.to_vec(),
                    data: data.clone(),
                })
            }
            Literal::Tuple(_) => Err(Error::new("reshape: literal is a tuple")),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => Err(Error::new("array_shape: literal is a tuple")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unwrap(data)
                .map(<[T]>::to_vec)
                .ok_or_else(|| Error::new("to_vec: element type mismatch")),
            Literal::Tuple(_) => Err(Error::new("to_vec: literal is a tuple")),
        }
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first()
            .copied()
            .ok_or_else(|| Error::new("get_first_element: empty literal"))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(elems) => Ok(elems),
            Literal::Array { .. } => Err(Error::new("to_tuple: literal is not a tuple")),
        }
    }

    /// Decompose a 1-tuple into its single element.
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut elems = self.to_tuple()?;
        if elems.len() != 1 {
            return Err(Error::new(format!("to_tuple1: arity {}", elems.len())));
        }
        Ok(elems.pop().unwrap())
    }
}

// Uninhabited: values of this type cannot exist in the stub, which
// makes every "after compile" method body statically unreachable.
#[derive(Clone, Debug)]
enum Void {}

/// Device buffer handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _void: Void,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self._void {}
    }
}

/// Compiled executable handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _void: Void,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._void {}
    }

    pub fn execute_b<L: Borrow<PjRtBuffer>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._void {}
    }
}

/// PJRT client stub: host metadata works, `compile` is the gate.
#[derive(Clone, Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self(()))
    }

    pub fn platform_name(&self) -> String {
        "cpu (stub)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "xla stub: compilation requires the real PJRT backend \
             (see rust/vendor/xla); rebuild with the real `xla` crate",
        ))
    }
}

/// Parsed HLO text (contents are not interpreted by the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        // Validate existence so registry errors stay meaningful.
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error::new(format!("no such HLO file: {}", path.display())));
        }
        Ok(Self(()))
    }
}

/// An unverified computation graph handle.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Graph node handle. The stub records nothing: graphs type-check and
/// "build", but only the real crate can lower them.
#[derive(Clone, Debug)]
pub struct XlaOp(());

/// Graph builder stub.
#[derive(Debug)]
pub struct XlaBuilder(());

impl XlaBuilder {
    pub fn new(_name: &str) -> XlaBuilder {
        XlaBuilder(())
    }

    pub fn parameter(
        &self,
        _id: i64,
        _ty: ElementType,
        _dims: &[i64],
        _name: &str,
    ) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn c0<T: NativeType>(&self, _v: T) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn tuple(&self, _ops: &[XlaOp]) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn build(&self, _root: &XlaOp) -> Result<XlaComputation> {
        Ok(XlaComputation(()))
    }
}

impl XlaOp {
    pub fn mul_(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn add_(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn div_(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn max(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn sqrt(&self) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn reduce_sum(&self, _dims: &[i64], _keep_dims: bool) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn matmul(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn transpose(&self, _perm: &[i64]) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn broadcast(&self, _dims: &[i64]) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn concat_in_dim(&self, _others: &[&XlaOp], _dim: i64) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn slice_in_dim1(&self, _start: i64, _stop: i64, _dim: i64) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }

    pub fn softmax(&self, _dim: i64) -> Result<XlaOp> {
        Ok(XlaOp(()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap().len(), 6);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        let t = Literal::Tuple(vec![s]);
        let inner = t.to_tuple1().unwrap();
        assert_eq!(inner.get_first_element::<f32>().unwrap(), 2.5);
    }

    #[test]
    fn compile_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let b = XlaBuilder::new("t");
        let x = b.parameter(0, ElementType::F32, &[2, 2], "x").unwrap();
        let computation = b.build(&x).unwrap();
        assert!(client.compile(&computation).is_err());
    }
}

//! Spill-file persistence for evicted decode sessions.
//!
//! When the byte-budgeted [`super::SessionStore`] evicts a session and
//! the spill tier is enabled, the whole per-layer state stack is
//! serialized to a single file and the next `decode_step` touching the
//! id restores it transparently — the resident → spilled → restored
//! lifecycle. Because the recurrent branch is O(d³) flat in N, a
//! spilled long-context session is small and cheap to rehydrate.
//!
//! ## File format (version 1, little-endian)
//!
//! ```text
//! magic    4 B   b"TSSP"
//! version  4 B   u32 = 1
//! checksum 8 B   FNV-1a 64 of the payload bytes
//! length   8 B   payload byte count
//! payload  …     session id u64 · trace id u64 · ModelSession encoding
//! ```
//!
//! Floats travel as raw IEEE-754 bits so a restore is **bit-exact**
//! with never-evicted state — the streaming parity guarantee survives
//! the disk round trip. A file that fails magic/version/checksum/shape
//! validation yields a typed [`SpillError`]; the store then deletes it
//! and degrades to the pre-spill behaviour (`NeedsReprefill`). All
//! fallible paths return errors — this module is in taylor-lint R3
//! (no-panic) scope.

use std::path::Path;

use crate::util::bytes::{fnv1a, ByteReader, ByteWriter, CodecError};

use super::streaming::{ModelSession, StreamingModel};

/// First four bytes of every spill file.
pub const SPILL_MAGIC: [u8; 4] = *b"TSSP";
/// Current on-disk format version.
pub const SPILL_VERSION: u32 = 1;
/// Fixed header size: magic + version + checksum + payload length.
pub const SPILL_HEADER_BYTES: u64 = 4 + 4 + 8 + 8;

/// Why a spill write or restore failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpillError {
    /// Filesystem error (message carried; `std::io::Error` is not
    /// `Clone`/`PartialEq`).
    Io(String),
    /// File shorter than the fixed header.
    Truncated,
    /// First four bytes are not `TSSP`.
    BadMagic,
    /// Header version this build does not understand.
    BadVersion { found: u32 },
    /// Payload bytes do not hash to the header checksum.
    ChecksumMismatch { expected: u64, found: u64 },
    /// Header length disagrees with the actual payload size.
    LengthMismatch { expected: u64, found: u64 },
    /// Payload structure invalid or inconsistent with the model.
    Codec(CodecError),
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(msg) => write!(f, "spill io error: {msg}"),
            Self::Truncated => write!(f, "spill file truncated"),
            Self::BadMagic => write!(f, "spill file has bad magic"),
            Self::BadVersion { found } => {
                write!(f, "spill file version {found} (expected {SPILL_VERSION})")
            }
            Self::ChecksumMismatch { expected, found } => write!(
                f,
                "spill checksum mismatch (header {expected:#018x}, payload {found:#018x})"
            ),
            Self::LengthMismatch { expected, found } => write!(
                f,
                "spill payload length mismatch (header {expected}, file {found})"
            ),
            Self::Codec(e) => write!(f, "spill payload invalid: {e}"),
        }
    }
}

impl std::error::Error for SpillError {}

impl From<CodecError> for SpillError {
    fn from(e: CodecError) -> Self {
        Self::Codec(e)
    }
}

/// A session rehydrated from disk.
pub struct SpilledSession {
    /// Stream id recorded at spill time.
    pub id: u64,
    /// Trace id recorded at spill time — restore continues the same
    /// trace, so the flight recorder shows one stream end to end.
    pub trace: u64,
    /// The restored per-layer state stack.
    pub session: ModelSession,
}

/// Size in bytes a spill of `session` would occupy on disk, without
/// serializing — used by the store's spill-budget admission check.
pub fn spill_file_size(session: &ModelSession) -> u64 {
    let mut w = ByteWriter::new();
    session.encode(&mut w);
    SPILL_HEADER_BYTES + 16 + w.len() as u64
}

/// Serialize `session` to `path` (creating parent dirs as needed) and
/// return the file size in bytes.
pub fn write_spill(
    path: &Path,
    id: u64,
    trace: u64,
    session: &ModelSession,
) -> Result<u64, SpillError> {
    let mut payload = ByteWriter::new();
    payload.put_u64(id);
    payload.put_u64(trace);
    session.encode(&mut payload);
    let payload = payload.into_bytes();

    let mut file = ByteWriter::new();
    file.put_u32(u32::from_le_bytes(SPILL_MAGIC));
    file.put_u32(SPILL_VERSION);
    file.put_u64(fnv1a(&payload));
    file.put_u64(payload.len() as u64);
    let mut bytes = file.into_bytes();
    bytes.extend_from_slice(&payload);

    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).map_err(|e| SpillError::Io(e.to_string()))?;
    }
    let len = bytes.len() as u64;
    std::fs::write(path, &bytes).map_err(|e| SpillError::Io(e.to_string()))?;
    Ok(len)
}

/// Read, validate, and decode a spill file. Validation order: magic,
/// version, payload length, checksum, then structural decode against
/// `model` — so corruption is attributed to the earliest broken layer.
pub fn read_spill(path: &Path, model: &StreamingModel) -> Result<SpilledSession, SpillError> {
    let bytes = std::fs::read(path).map_err(|e| SpillError::Io(e.to_string()))?;
    if (bytes.len() as u64) < SPILL_HEADER_BYTES {
        return Err(SpillError::Truncated);
    }
    let mut r = ByteReader::new(&bytes);
    let magic = r.get_u32().map_err(|_| SpillError::Truncated)?;
    if magic.to_le_bytes() != SPILL_MAGIC {
        return Err(SpillError::BadMagic);
    }
    let version = r.get_u32().map_err(|_| SpillError::Truncated)?;
    if version != SPILL_VERSION {
        return Err(SpillError::BadVersion { found: version });
    }
    let checksum = r.get_u64().map_err(|_| SpillError::Truncated)?;
    let payload_len = r.get_u64().map_err(|_| SpillError::Truncated)?;
    let found_len = r.remaining() as u64;
    if payload_len != found_len {
        return Err(SpillError::LengthMismatch {
            expected: payload_len,
            found: found_len,
        });
    }
    let payload = &bytes[SPILL_HEADER_BYTES as usize..];
    let found = fnv1a(payload);
    if found != checksum {
        return Err(SpillError::ChecksumMismatch {
            expected: checksum,
            found,
        });
    }
    let mut r = ByteReader::new(payload);
    let id = r.get_u64().map_err(SpillError::from)?;
    let trace = r.get_u64().map_err(SpillError::from)?;
    let session = ModelSession::decode(&mut r, model)?;
    if r.remaining() != 0 {
        return Err(SpillError::Codec(CodecError::Invalid {
            what: "trailing bytes after session",
        }));
    }
    Ok(SpilledSession { id, trace, session })
}

/// Best-effort spill-file removal; the store calls this on restore,
/// close, tombstone aging, and corruption — a failed unlink only
/// leaks disk, never correctness.
pub fn remove_spill(path: &Path) {
    let _ = std::fs::remove_file(path);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::DecodeConfig;
    use crate::model::ModelConfig;
    use crate::tensor::Tensor;
    use std::path::PathBuf;

    fn test_model() -> StreamingModel {
        let decode = DecodeConfig {
            heads: 2,
            n_layers: 2,
            d_ff: 24,
            ..DecodeConfig::default()
        };
        StreamingModel::new(ModelConfig::from_decode(&decode, 4))
    }

    fn test_session(model: &StreamingModel, steps: usize) -> ModelSession {
        let mut session =
            ModelSession::with_thresholds(model, &[false, false], vec![Some(3.0), None]);
        let x = Tensor::randn(&[steps, model.d_model()], 99);
        for t in 0..steps {
            let token = Tensor::new(&[1, model.d_model()], x.row(t).to_vec());
            model.step(&mut session, &token);
        }
        session
    }

    fn temp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ts-spill-test-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_restores_id_trace_and_state() {
        let model = test_model();
        let session = test_session(&model, 5);
        let want_bytes = session.state_bytes();
        let path = temp_path("roundtrip.spill");
        let file_bytes = write_spill(&path, 7, 0xabcd, &session).unwrap();
        assert_eq!(file_bytes, spill_file_size(&session));
        let back = read_spill(&path, &model).unwrap();
        remove_spill(&path);
        assert_eq!(back.id, 7);
        assert_eq!(back.trace, 0xabcd);
        assert_eq!(back.session.len(), session.len());
        assert_eq!(back.session.state_bytes(), want_bytes);
        assert_eq!(back.session.branches(), session.branches());
    }

    #[test]
    fn corrupt_payload_fails_checksum() {
        let model = test_model();
        let session = test_session(&model, 4);
        let path = temp_path("corrupt.spill");
        write_spill(&path, 1, 2, &session).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_spill(&path, &model).unwrap_err();
        remove_spill(&path);
        assert!(matches!(err, SpillError::ChecksumMismatch { .. }), "got {err:?}");
    }

    #[test]
    fn truncated_file_reports_length_mismatch() {
        let model = test_model();
        let session = test_session(&model, 4);
        let path = temp_path("truncated.spill");
        write_spill(&path, 1, 2, &session).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        let err = read_spill(&path, &model).unwrap_err();
        remove_spill(&path);
        assert!(matches!(err, SpillError::LengthMismatch { .. }), "got {err:?}");
    }

    #[test]
    fn header_smaller_than_fixed_size_is_truncated() {
        let path = temp_path("tiny.spill");
        std::fs::write(&path, b"TSS").unwrap();
        let err = read_spill(&path, &test_model()).unwrap_err();
        remove_spill(&path);
        assert_eq!(err, SpillError::Truncated);
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let model = test_model();
        let session = test_session(&model, 3);
        let path = temp_path("magic.spill");
        write_spill(&path, 1, 2, &session).unwrap();
        let good = std::fs::read(&path).unwrap();

        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert_eq!(read_spill(&path, &model).unwrap_err(), SpillError::BadMagic);

        let mut bad = good.clone();
        bad[4] = 9; // version 9
        std::fs::write(&path, &bad).unwrap();
        assert_eq!(
            read_spill(&path, &model).unwrap_err(),
            SpillError::BadVersion { found: 9 }
        );
        remove_spill(&path);
    }

    #[test]
    fn wrong_model_shape_is_codec_error() {
        let model = test_model();
        let session = test_session(&model, 3);
        let path = temp_path("shape.spill");
        write_spill(&path, 1, 2, &session).unwrap();
        let deeper = StreamingModel::new(ModelConfig::from_decode(
            &DecodeConfig {
                heads: 2,
                n_layers: 3,
                d_ff: 24,
                ..DecodeConfig::default()
            },
            4,
        ));
        let err = read_spill(&path, &deeper).unwrap_err();
        remove_spill(&path);
        assert!(matches!(err, SpillError::Codec(_)), "got {err:?}");
    }

    #[test]
    fn missing_file_is_io_error() {
        let err = read_spill(&temp_path("nope.spill"), &test_model()).unwrap_err();
        assert!(matches!(err, SpillError::Io(_)));
    }
}

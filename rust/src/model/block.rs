//! One transformer block: pre-LN → TaylorShift multi-head attention →
//! residual → pre-LN → MLP (GELU) → residual.
//!
//! The block exposes two evaluation paths over the *same* weights:
//!
//! * [`Block::forward_batch`] — causal attention over an `[n, d_model]`
//!   prefix via [`causal_taylor`], the whole-sequence reference;
//! * [`Block::stream_step`] — one `[1, d_model]` token against a
//!   resident [`DecodeSession`] (KV cache or recurrent moments).
//!
//! Every non-attention op here (LayerNorm, projections, bias add,
//! GELU, residuals) is computed per row, and `Tensor::matmul`
//! accumulates each output row independently of the batch size — so
//! the two paths agree *bitwise* on every row, which is what the
//! whole-model parity tests rely on.

use crate::attention::causal::causal_taylor;
use crate::decode::session::{DecodeSession, StepResult};
use crate::tensor::Tensor;

/// Row-wise LayerNorm with learned gain/bias; statistics in f64.
pub fn layer_norm(x: &Tensor, gamma: &[f32], beta: &[f32]) -> Tensor {
    assert_eq!(x.rank(), 2, "layer_norm expects [n, d]");
    let (n, d) = (x.shape()[0], x.shape()[1]);
    assert_eq!(gamma.len(), d, "gamma length mismatch");
    assert_eq!(beta.len(), d, "beta length mismatch");
    let mut out = Tensor::zeros(&[n, d]);
    for i in 0..n {
        let row = x.row(i);
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row
            .iter()
            .map(|&v| {
                let c = v as f64 - mean;
                c * c
            })
            .sum::<f64>()
            / d as f64;
        let inv = 1.0 / (var + 1e-6).sqrt();
        for (c, o) in out.row_mut(i).iter_mut().enumerate() {
            *o = ((row[c] as f64 - mean) * inv * gamma[c] as f64 + beta[c] as f64) as f32;
        }
    }
    out
}

/// GELU (tanh approximation), evaluated in f64 per element.
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f64 = 0.797_884_560_802_865_4;
    let x = x as f64;
    (0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())) as f32
}

/// Add a bias vector to every row.
fn add_row_bias(x: &Tensor, bias: &[f32]) -> Tensor {
    assert_eq!(x.shape()[1], bias.len(), "bias length mismatch");
    let n = x.shape()[0];
    let mut out = x.clone();
    for i in 0..n {
        for (o, &b) in out.row_mut(i).iter_mut().zip(bias) {
            *o += b;
        }
    }
    out
}

/// Copy columns `[start, start + width)` of a `[n, m]` tensor into a
/// fresh `[n, width]` tensor (per-head slicing).
fn col_slice(x: &Tensor, start: usize, width: usize) -> Tensor {
    let n = x.shape()[0];
    let mut out = Tensor::zeros(&[n, width]);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&x.row(i)[start..start + width]);
    }
    out
}

/// One pre-LN transformer block with TaylorShift attention.
pub struct Block {
    heads: usize,
    head_dim: usize,
    tau: f32,
    ln1_gamma: Vec<f32>,
    ln1_beta: Vec<f32>,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ln2_gamma: Vec<f32>,
    ln2_beta: Vec<f32>,
    w1: Tensor,
    b1: Vec<f32>,
    w2: Tensor,
    b2: Vec<f32>,
}

impl Block {
    /// Deterministic seeded init: projection weights N(0, 1/fan_in),
    /// LayerNorm at identity, small random biases.
    pub fn new(heads: usize, head_dim: usize, d_ff: usize, tau: f32, seed: u64) -> Self {
        assert!(heads > 0 && head_dim > 0 && d_ff > 0, "block dims must be positive");
        let dm = heads * head_dim;
        let proj_scale = 1.0 / (dm as f32).sqrt();
        let ff_scale = 1.0 / (d_ff as f32).sqrt();
        Self {
            heads,
            head_dim,
            tau,
            ln1_gamma: vec![1.0; dm],
            ln1_beta: vec![0.0; dm],
            wq: Tensor::randn(&[dm, dm], seed.wrapping_add(1)).scale(proj_scale),
            wk: Tensor::randn(&[dm, dm], seed.wrapping_add(2)).scale(proj_scale),
            wv: Tensor::randn(&[dm, dm], seed.wrapping_add(3)).scale(proj_scale),
            wo: Tensor::randn(&[dm, dm], seed.wrapping_add(4)).scale(proj_scale),
            ln2_gamma: vec![1.0; dm],
            ln2_beta: vec![0.0; dm],
            w1: Tensor::randn(&[dm, d_ff], seed.wrapping_add(5)).scale(proj_scale),
            b1: Tensor::randn(&[1, d_ff], seed.wrapping_add(6))
                .scale(0.02)
                .into_data(),
            w2: Tensor::randn(&[d_ff, dm], seed.wrapping_add(7)).scale(ff_scale),
            b2: Tensor::randn(&[1, dm], seed.wrapping_add(8))
                .scale(0.02)
                .into_data(),
        }
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn d_model(&self) -> usize {
        self.heads * self.head_dim
    }

    pub fn tau(&self) -> f32 {
        self.tau
    }

    /// MLP sub-layer: `gelu(x·W1 + b1)·W2 + b2`, row-wise.
    fn mlp(&self, x: &Tensor) -> Tensor {
        let h = add_row_bias(&x.matmul(&self.w1), &self.b1).map(gelu);
        add_row_bias(&h.matmul(&self.w2), &self.b2)
    }

    /// Batch forward over an `[n, d_model]` prefix with causal
    /// attention. `promote_at` is forwarded to [`causal_taylor`] per
    /// head, mirroring this layer's decode-state promotion point.
    pub fn forward_batch(&self, x: &Tensor, promote_at: Option<usize>) -> Tensor {
        let dm = self.d_model();
        assert_eq!(x.rank(), 2, "block input must be [n, d_model]");
        assert_eq!(x.shape()[1], dm, "block width mismatch");
        let n = x.shape()[0];
        let a = layer_norm(x, &self.ln1_gamma, &self.ln1_beta);
        let q = a.matmul(&self.wq);
        let k = a.matmul(&self.wk);
        let v = a.matmul(&self.wv);
        let mut attn = Tensor::zeros(&[n, dm]);
        for h in 0..self.heads {
            let (lo, width) = (h * self.head_dim, self.head_dim);
            let qh = col_slice(&q, lo, width);
            let kh = col_slice(&k, lo, width);
            let vh = col_slice(&v, lo, width);
            let yh = causal_taylor(&qh, &kh, &vh, self.tau, promote_at);
            for i in 0..n {
                attn.row_mut(i)[lo..lo + width].copy_from_slice(yh.row(i));
            }
        }
        let res = x.add(&attn.matmul(&self.wo));
        let m = self.mlp(&layer_norm(&res, &self.ln2_gamma, &self.ln2_beta));
        res.add(&m)
    }

    /// One streaming token through this block: project the `[1,
    /// d_model]` row, feed the per-head q/k/v to this layer's resident
    /// `DecodeSession` (which may promote at `crossover`), and finish
    /// the block on the attention output. Returns the block output and
    /// the session's step record.
    pub fn stream_step(
        &self,
        x: &Tensor,
        state: &mut DecodeSession,
        crossover: Option<f64>,
    ) -> (Tensor, StepResult) {
        let dm = self.d_model();
        assert_eq!(x.shape(), &[1, dm], "stream input must be [1, d_model]");
        let a = layer_norm(x, &self.ln1_gamma, &self.ln1_beta);
        let q = a.matmul(&self.wq).reshape(&[self.heads, self.head_dim]);
        let k = a.matmul(&self.wk).reshape(&[self.heads, self.head_dim]);
        let v = a.matmul(&self.wv).reshape(&[self.heads, self.head_dim]);
        let r = state.step(&q, &k, &v, crossover);
        let attn = Tensor::new(&[1, dm], r.output.clone());
        let res = x.add(&attn.matmul(&self.wo));
        let m = self.mlp(&layer_norm(&res, &self.ln2_gamma, &self.ln2_beta));
        (res.add(&m), r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_norm_normalizes_rows() {
        let x = Tensor::randn(&[4, 16], 3);
        let y = layer_norm(&x, &vec![1.0; 16], &vec![0.0; 16]);
        for i in 0..4 {
            let row = y.row(i);
            let mean: f64 = row.iter().map(|&v| v as f64).sum::<f64>() / 16.0;
            let var: f64 = row
                .iter()
                .map(|&v| {
                    let c = v as f64 - mean;
                    c * c
                })
                .sum::<f64>()
                / 16.0;
            assert!(mean.abs() < 1e-5, "row {i} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {i} var {var}");
        }
    }

    #[test]
    fn gelu_known_values() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4, "strongly negative input gates to ~0");
        assert!((gelu(10.0) - 10.0).abs() < 1e-4, "strongly positive input passes");
    }

    /// Block-level version of the whole-model parity claim: a single
    /// block streamed token-by-token is bit-identical to its batch
    /// forward, across a mid-stream promotion.
    #[test]
    fn stream_matches_batch_bitwise() {
        let (heads, head_dim, d_ff, tau) = (2usize, 4usize, 16usize, 1.1f32);
        let block = Block::new(heads, head_dim, d_ff, tau, 99);
        let n = 12usize;
        let promote = 5usize;
        let x = Tensor::randn(&[n, block.d_model()], 1234);
        let batch = block.forward_batch(&x, Some(promote));
        let mut session = DecodeSession::new(heads, head_dim, tau, false);
        for t in 0..n {
            let token = Tensor::new(&[1, block.d_model()], x.row(t).to_vec());
            let (y, r) = block.stream_step(&token, &mut session, Some(promote as f64));
            assert_eq!(r.promoted, t + 1 == promote, "step {}", t + 1);
            assert_eq!(y.row(0), batch.row(t), "row {t} must be bit-exact");
        }
        assert_eq!(session.promoted_at(), Some(promote));
    }
}

//! LRU-evicting, byte-budgeted store of resident [`ModelSession`]s.
//!
//! Byte accounting sums every layer's decode state (KV caches grow
//! with the prefix; recurrent moments are flat), so a long-prefix
//! unpromoted stream weighs L times its single-layer cost. When the
//! budget or the session cap is exceeded, least-recently-used sessions
//! are evicted — and remembered, so a client stepping an evicted
//! stream gets a typed [`StepMiss::Evicted`] ("re-prefill required")
//! instead of a panic or a silently fresh state.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::attention::selector::Selector;
use crate::attention::AttentionVariant;
use crate::decode::DecodeConfig;
use crate::tensor::Tensor;

use super::streaming::{ModelSession, ModelStepResult, StreamingModel};
use super::ModelConfig;

/// Why a store-level step could not run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepMiss {
    /// The id was never opened (or was closed normally).
    Unknown,
    /// The session was LRU-evicted under memory pressure; the client
    /// must re-prefill before streaming again.
    Evicted,
}

/// Outcome of a store-level decode step.
pub struct StepOutcome {
    pub result: ModelStepResult,
    /// Sessions LRU-evicted to make room during this operation.
    pub evicted: Vec<u64>,
}

/// Closing summary for a finished session.
#[derive(Clone, Debug)]
pub struct SessionSummary {
    pub tokens: usize,
    /// Branch serving each layer at close time.
    pub branches: Vec<AttentionVariant>,
    pub bytes: u64,
    /// Per-layer promotion points (`None` = layer stayed KV).
    pub promoted_at: Vec<Option<usize>>,
    /// The session's observability trace ID.
    pub trace: u64,
}

struct Resident {
    session: ModelSession,
    last_used: u64,
    bytes: u64,
    /// Observability trace ID minted at open; every span and
    /// flight-recorder event for this stream carries it.
    trace: u64,
}

/// Keeps whole-model streaming sessions resident under a byte budget.
pub struct SessionStore {
    cfg: DecodeConfig,
    model: StreamingModel,
    selector: Selector,
    forced: Option<AttentionVariant>,
    sessions: HashMap<u64, Resident>,
    evicted_ids: HashSet<u64>,
    evicted_order: VecDeque<u64>,
    clock: u64,
    resident_bytes: u64,
}

impl SessionStore {
    /// Bound on remembered evictions: old entries age out FIFO so the
    /// tombstone set cannot grow without limit.
    const EVICTED_MEMORY: usize = 1024;

    /// `forced` mirrors the engine's variant override: `Direct` pins
    /// every layer to the KV path (never promote), `Efficient` starts
    /// them all recurrent. `Softmax` has no streaming form and falls
    /// back to the selector policy.
    pub fn new(
        cfg: DecodeConfig,
        head_dim: usize,
        selector: Selector,
        forced: Option<AttentionVariant>,
    ) -> Self {
        let model = StreamingModel::new(ModelConfig::from_decode(&cfg, head_dim));
        Self {
            cfg,
            model,
            selector,
            forced,
            sessions: HashMap::new(),
            evicted_ids: HashSet::new(),
            evicted_order: VecDeque::new(),
            clock: 0,
            resident_bytes: 0,
        }
    }

    /// The deterministic model every session streams through.
    pub fn model(&self) -> &StreamingModel {
        &self.model
    }

    pub fn config(&self) -> &DecodeConfig {
        &self.cfg
    }

    /// Resident session count.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Total bytes held by resident session state, all layers summed.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// True iff `id` was LRU-evicted (and not reopened since).
    pub fn was_evicted(&self, id: u64) -> bool {
        self.evicted_ids.contains(&id)
    }

    /// The observability trace ID of a resident session.
    pub fn trace_of(&self, id: u64) -> Option<u64> {
        self.sessions.get(&id).map(|r| r.trace)
    }

    /// Open (or reset) a session. Returns ids evicted to fit it.
    pub fn open(&mut self, id: u64) -> Vec<u64> {
        self.forget_eviction(id);
        if let Some(old) = self.sessions.remove(&id) {
            self.resident_bytes -= old.bytes;
        }
        let session = ModelSession::new(&self.model, &self.selector, self.forced);
        let bytes = session.state_bytes();
        self.clock += 1;
        self.resident_bytes += bytes;
        self.sessions.insert(
            id,
            Resident {
                session,
                last_used: self.clock,
                bytes,
                trace: crate::obs::next_trace_id(),
            },
        );
        self.enforce_budget(Some(id))
    }

    /// One whole-model decode step for session `id`.
    pub fn step(&mut self, id: u64, token: &Tensor) -> Result<StepOutcome, StepMiss> {
        self.clock += 1;
        let clock = self.clock;
        let model = &self.model;
        let Some(entry) = self.sessions.get_mut(&id) else {
            return Err(if self.evicted_ids.contains(&id) {
                StepMiss::Evicted
            } else {
                StepMiss::Unknown
            });
        };
        let before = entry.bytes;
        let result = model.step(&mut entry.session, token);
        let after = entry.session.state_bytes();
        entry.bytes = after;
        entry.last_used = clock;
        // `before` is included in the resident total, so this never underflows.
        self.resident_bytes = self.resident_bytes - before + after;
        let evicted = self.enforce_budget(Some(id));
        Ok(StepOutcome { result, evicted })
    }

    /// Drop a session normally, returning its closing summary. A
    /// closed session is *not* recorded as evicted — stepping it again
    /// yields [`StepMiss::Unknown`].
    pub fn close(&mut self, id: u64) -> Option<SessionSummary> {
        let entry = self.sessions.remove(&id)?;
        self.resident_bytes -= entry.bytes;
        Some(SessionSummary {
            tokens: entry.session.len(),
            branches: entry.session.branches(),
            bytes: entry.bytes,
            promoted_at: entry.session.promoted_at(),
            trace: entry.trace,
        })
    }

    /// Per-layer branch occupancy across resident sessions: for each
    /// layer, how many sessions it serves on (KV, recurrent).
    pub fn layer_occupancy(&self) -> (Vec<u64>, Vec<u64>) {
        let n = self.model.config().n_layers;
        let mut kv = vec![0u64; n];
        let mut recurrent = vec![0u64; n];
        for entry in self.sessions.values() {
            for (l, b) in entry.session.branches().iter().enumerate() {
                match b {
                    AttentionVariant::Efficient => recurrent[l] += 1,
                    _ => kv[l] += 1,
                }
            }
        }
        (kv, recurrent)
    }

    fn forget_eviction(&mut self, id: u64) {
        if self.evicted_ids.remove(&id) {
            self.evicted_order.retain(|&e| e != id);
        }
    }

    fn record_eviction(&mut self, id: u64) {
        if self.evicted_ids.insert(id) {
            self.evicted_order.push_back(id);
            while self.evicted_order.len() > Self::EVICTED_MEMORY {
                if let Some(old) = self.evicted_order.pop_front() {
                    self.evicted_ids.remove(&old);
                }
            }
        }
    }

    /// Evict LRU sessions until both the byte budget and the session
    /// cap hold. The session named by `protect` (the one being
    /// operated on) is never evicted.
    fn enforce_budget(&mut self, protect: Option<u64>) -> Vec<u64> {
        let mut evicted = Vec::new();
        loop {
            let over_bytes = self.resident_bytes > self.cfg.max_session_bytes;
            let over_count = self.sessions.len() > self.cfg.max_sessions;
            if !over_bytes && !over_count {
                break;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(id, _)| Some(**id) != protect)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(id, _)| *id);
            let Some(victim) = victim else {
                break; // only the protected session remains
            };
            // The victim id was taken from `sessions` under `&mut self`,
            // so the remove can only miss if that invariant broke — stop
            // evicting rather than panic mid-request.
            let Some(gone) = self.sessions.remove(&victim) else {
                break;
            };
            self.resident_bytes -= gone.bytes;
            crate::obs::recorder::record_event(
                crate::obs::recorder::EventKind::Evict,
                gone.trace,
                victim,
                gone.bytes,
            );
            self.record_eviction(victim);
            evicted.push(victim);
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> DecodeConfig {
        DecodeConfig {
            heads: 1,
            n_layers: 1,
            d_ff: 16,
            ..DecodeConfig::default()
        }
    }

    fn token(d_model: usize, seed: u64) -> Tensor {
        Tensor::randn(&[1, d_model], seed)
    }

    #[test]
    fn store_evicts_lru_under_byte_budget() {
        let d = 8usize;
        let cfg = DecodeConfig {
            // Room for roughly two single-layer KV sessions of ~12 tokens.
            max_session_bytes: 2 * 12 * 2 * d as u64 * 4,
            max_sessions: 16,
            ..small_cfg()
        };
        let mut store =
            SessionStore::new(cfg, d, Selector::analytical(), Some(AttentionVariant::Direct));
        let t = token(d, 7);
        store.open(1);
        store.open(2);
        store.open(3);
        let mut all_evicted = Vec::new();
        for _ in 0..12 {
            for id in [1u64, 2, 3] {
                if store.contains(id) {
                    let out = store.step(id, &t).unwrap();
                    all_evicted.extend(out.evicted);
                }
            }
        }
        assert!(!all_evicted.is_empty(), "budget never triggered eviction");
        assert!(store.resident_bytes() <= store.config().max_session_bytes);
        // Evicted sessions miss with the typed re-prefill error.
        let gone = all_evicted[0];
        assert_eq!(store.step(gone, &t).unwrap_err(), StepMiss::Evicted);
    }

    #[test]
    fn store_caps_session_count() {
        let cfg = DecodeConfig {
            max_sessions: 2,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        assert!(store.open(1).is_empty());
        assert!(store.open(2).is_empty());
        let evicted = store.open(3);
        assert_eq!(evicted, vec![1], "oldest session evicted");
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn lru_order_follows_use_not_creation() {
        let cfg = DecodeConfig {
            max_sessions: 2,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        let t = token(4, 9);
        store.open(1);
        store.open(2);
        store.step(1, &t).unwrap(); // 1 is now most recent
        let evicted = store.open(3);
        assert_eq!(evicted, vec![2]);
        assert!(store.contains(1) && store.contains(3));
    }

    #[test]
    fn forced_direct_never_promotes() {
        let mut store = SessionStore::new(
            small_cfg(),
            2, // crossover N0(2) is tiny — would promote immediately
            Selector::analytical(),
            Some(AttentionVariant::Direct),
        );
        let t = token(2, 3);
        store.open(5);
        for _ in 0..32 {
            let out = store.step(5, &t).unwrap();
            for ls in &out.result.layers {
                assert_eq!(ls.branch, AttentionVariant::Direct);
                assert!(!ls.promoted);
            }
        }
    }

    #[test]
    fn forced_efficient_starts_recurrent() {
        let mut store = SessionStore::new(
            small_cfg(),
            16,
            Selector::analytical(),
            Some(AttentionVariant::Efficient),
        );
        let t = token(16, 4);
        store.open(5);
        let out = store.step(5, &t).unwrap();
        for ls in &out.result.layers {
            assert_eq!(ls.branch, AttentionVariant::Efficient);
            assert!(!ls.promoted, "no promotion event when born recurrent");
        }
    }

    #[test]
    fn close_reports_summary_and_frees_bytes() {
        let cfg = DecodeConfig {
            heads: 2,
            n_layers: 2,
            d_ff: 16,
            ..DecodeConfig::default()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        let t = token(8, 11);
        store.open(9);
        for _ in 0..3 {
            store.step(9, &t).unwrap();
        }
        let summary = store.close(9).unwrap();
        assert_eq!(summary.tokens, 3);
        assert_eq!(summary.branches.len(), 2);
        assert_eq!(summary.promoted_at.len(), 2);
        assert_eq!(store.resident_bytes(), 0);
        assert!(store.close(9).is_none());
        // Closed ≠ evicted: the next step is Unknown, not Evicted.
        assert_eq!(store.step(9, &t).unwrap_err(), StepMiss::Unknown);
    }

    #[test]
    fn unknown_session_misses_as_unknown() {
        let mut store = SessionStore::new(small_cfg(), 4, Selector::analytical(), None);
        assert_eq!(store.step(99, &token(4, 1)).unwrap_err(), StepMiss::Unknown);
    }

    #[test]
    fn reopen_clears_eviction_tombstone() {
        let cfg = DecodeConfig {
            max_sessions: 1,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        store.open(1);
        let evicted = store.open(2);
        assert_eq!(evicted, vec![1]);
        assert!(store.was_evicted(1));
        let t = token(4, 2);
        assert_eq!(store.step(1, &t).unwrap_err(), StepMiss::Evicted);
        store.open(1); // re-prefill path: reopen after eviction
        assert!(!store.was_evicted(1));
        assert!(store.step(1, &t).is_ok());
    }

    #[test]
    fn layer_occupancy_counts_branches() {
        let cfg = DecodeConfig {
            n_layers: 2,
            d_ff: 16,
            ..small_cfg()
        };
        let mut store = SessionStore::new(
            cfg,
            4,
            Selector::analytical(),
            Some(AttentionVariant::Direct),
        );
        store.open(1);
        store.open(2);
        let (kv, recurrent) = store.layer_occupancy();
        assert_eq!(kv, vec![2, 2]);
        assert_eq!(recurrent, vec![0, 0]);
    }
}

//! LRU-evicting, byte-budgeted store of resident [`ModelSession`]s,
//! with an optional disk spill tier.
//!
//! Byte accounting sums every layer's decode state (KV caches grow
//! with the prefix; recurrent moments are flat), so a long-prefix
//! unpromoted stream weighs L times its single-layer cost. When the
//! budget or the session cap is exceeded, least-recently-used sessions
//! are evicted.
//!
//! What eviction *means* depends on the spill tier
//! ([`crate::decode::SpillConfig`]):
//!
//! * **Spill disabled** — the state is destroyed and remembered as a
//!   tombstone; a client stepping the id gets a typed
//!   [`StepMiss::Evicted`] ("re-prefill required").
//! * **Spill enabled** — the state is serialized to a checksummed
//!   spill file under the spill byte budget (oldest spill files are
//!   dropped to make room — second-level eviction), and the next step
//!   touching the id **restores it transparently**, evicting other
//!   residents as needed. `Evicted` then only surfaces when the spill
//!   budget pushed the file out, and [`StepMiss::SpillFailed`] when
//!   the file fails checksum/version/shape validation.
//!
//! The lifecycle is resident → spilled → restored; restores are
//! bit-exact (see `model/spill.rs`), so a restored stream is
//! indistinguishable from one that was never evicted.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::time::Duration;

use crate::attention::selector::Selector;
use crate::attention::AttentionVariant;
use crate::decode::{DecodeConfig, SpillConfig};
use crate::tensor::Tensor;

use super::spill::{self, SpillError};
use super::streaming::{ModelSession, ModelStepResult, StreamingModel};
use super::ModelConfig;

/// Why a store-level step could not run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepMiss {
    /// The id was never opened (or was closed normally).
    Unknown,
    /// The session was LRU-evicted and its state is gone (spill
    /// disabled, spill budget exhausted, or a failed restore); the
    /// client must re-prefill before streaming again.
    Evicted,
    /// The session had a spill file but restoring it failed
    /// validation; the file has been deleted and the session is now
    /// hard-evicted. Carries the typed reason.
    SpillFailed(SpillError),
}

/// One session pushed out of residency during an operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Eviction {
    pub id: u64,
    /// Resident state bytes freed.
    pub bytes: u64,
    /// True iff the state survived to a spill file (restorable);
    /// false means the state was destroyed.
    pub spilled: bool,
}

/// Accounting for a transparent restore performed by [`SessionStore::step`].
#[derive(Clone, Copy, Debug)]
pub struct RestoreReport {
    /// Resident state bytes rehydrated from disk.
    pub bytes: u64,
    /// Wall time of the read+validate+decode.
    pub elapsed: Duration,
}

/// Outcome of a store-level decode step.
pub struct StepOutcome {
    pub result: ModelStepResult,
    /// Sessions pushed out of residency to make room during this
    /// operation (spilled or destroyed — see [`Eviction::spilled`]).
    pub evicted: Vec<Eviction>,
    /// Present iff this step transparently restored the session from
    /// its spill file first.
    pub restored: Option<RestoreReport>,
}

/// Closing summary for a finished session.
#[derive(Clone, Debug)]
pub struct SessionSummary {
    pub tokens: usize,
    /// Branch serving each layer at close time (for a non-resident
    /// session: the branches at eviction time).
    pub branches: Vec<AttentionVariant>,
    pub bytes: u64,
    /// Per-layer promotion points (`None` = layer stayed KV).
    pub promoted_at: Vec<Option<usize>>,
    /// The session's observability trace ID.
    pub trace: u64,
    /// True iff the session was closed while evicted or spilled — the
    /// summary then reports what was known at eviction time.
    pub evicted: bool,
}

struct Resident {
    session: ModelSession,
    last_used: u64,
    bytes: u64,
    /// Observability trace ID minted at open; every span and
    /// flight-recorder event for this stream carries it. Survives the
    /// spill round trip, so one stream stays one trace.
    trace: u64,
}

/// On-disk record backing a spilled tombstone.
struct SpillRecord {
    path: PathBuf,
    file_bytes: u64,
}

/// What the store remembers about a non-resident session.
struct Tombstone {
    trace: u64,
    tokens: usize,
    branches: Vec<AttentionVariant>,
    promoted_at: Vec<Option<usize>>,
    state_bytes: u64,
    /// `Some` while the state lives in a restorable spill file.
    spill: Option<SpillRecord>,
}

/// Process-wide tag so two stores sharing a spill dir (each minting
/// stream ids from 1) never collide on file names.
fn next_store_tag() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Keeps whole-model streaming sessions resident under a byte budget.
pub struct SessionStore {
    cfg: DecodeConfig,
    model: StreamingModel,
    selector: Selector,
    forced: Option<AttentionVariant>,
    sessions: HashMap<u64, Resident>,
    evicted: HashMap<u64, Tombstone>,
    /// Tombstone ages, FIFO (both spilled and destroyed).
    evicted_order: VecDeque<u64>,
    /// Spilled ids in spill order — the second-level eviction queue.
    spill_order: VecDeque<u64>,
    /// Resolved spill directory (None iff spill disabled).
    spill_dir: Option<PathBuf>,
    /// On-disk budget for spill files.
    spill_budget: u64,
    store_tag: u64,
    clock: u64,
    resident_bytes: u64,
    spilled_bytes: u64,
}

impl SessionStore {
    /// Bound on remembered evictions: old entries age out FIFO so the
    /// tombstone set cannot grow without limit (aging a spilled
    /// tombstone deletes its file).
    const EVICTED_MEMORY: usize = 1024;

    /// `forced` mirrors the engine's variant override: `Direct` pins
    /// every layer to the KV path (never promote), `Efficient` starts
    /// them all recurrent. `Softmax` has no streaming form and falls
    /// back to the selector policy.
    pub fn new(
        cfg: DecodeConfig,
        head_dim: usize,
        selector: Selector,
        forced: Option<AttentionVariant>,
    ) -> Self {
        let model = StreamingModel::new(ModelConfig::from_decode(&cfg, head_dim));
        let spill_dir = if cfg.spill.enabled {
            Some(cfg.spill.dir.clone().unwrap_or_else(|| {
                std::env::temp_dir().join(format!("taylorshift-spill-{}", std::process::id()))
            }))
        } else {
            None
        };
        let spill_budget = if cfg.spill.max_bytes == 0 {
            SpillConfig::DEFAULT_MAX_BYTES
        } else {
            cfg.spill.max_bytes
        };
        Self {
            cfg,
            model,
            selector,
            forced,
            sessions: HashMap::new(),
            evicted: HashMap::new(),
            evicted_order: VecDeque::new(),
            spill_order: VecDeque::new(),
            spill_dir,
            spill_budget,
            store_tag: next_store_tag(),
            clock: 0,
            resident_bytes: 0,
            spilled_bytes: 0,
        }
    }

    /// The deterministic model every session streams through.
    pub fn model(&self) -> &StreamingModel {
        &self.model
    }

    pub fn config(&self) -> &DecodeConfig {
        &self.cfg
    }

    /// Resident session count.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Total bytes held by resident session state, all layers summed.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Sessions currently parked in spill files.
    pub fn spilled_sessions(&self) -> usize {
        self.spill_order.len()
    }

    /// On-disk bytes currently held by spill files.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// True iff `id` was LRU-evicted (spilled or destroyed) and not
    /// reopened since.
    pub fn was_evicted(&self, id: u64) -> bool {
        self.evicted.contains_key(&id)
    }

    /// True iff `id` currently has a restorable spill file.
    pub fn was_spilled(&self, id: u64) -> bool {
        self.evicted.get(&id).is_some_and(|t| t.spill.is_some())
    }

    /// The observability trace ID of a session — resident or spilled
    /// or tombstoned; a stream keeps one trace for its whole life.
    pub fn trace_of(&self, id: u64) -> Option<u64> {
        self.sessions
            .get(&id)
            .map(|r| r.trace)
            .or_else(|| self.evicted.get(&id).map(|t| t.trace))
    }

    /// Open (or reset) a session. Returns sessions evicted to fit it.
    pub fn open(&mut self, id: u64) -> Vec<Eviction> {
        self.forget_tombstone(id);
        if let Some(old) = self.sessions.remove(&id) {
            self.resident_bytes -= old.bytes;
        }
        let session = ModelSession::new(&self.model, &self.selector, self.forced);
        let bytes = session.state_bytes();
        self.clock += 1;
        self.resident_bytes += bytes;
        self.sessions.insert(
            id,
            Resident {
                session,
                last_used: self.clock,
                bytes,
                trace: crate::obs::next_trace_id(),
            },
        );
        self.enforce_budget(Some(id))
    }

    /// One whole-model decode step for session `id`. A spilled session
    /// is restored from disk first — transparently, under the
    /// `decode.restore` span — so callers only see a miss when the
    /// state is actually gone.
    pub fn step(&mut self, id: u64, token: &Tensor) -> Result<StepOutcome, StepMiss> {
        self.clock += 1;
        let mut restored = None;
        let mut restore_evictions = Vec::new();
        if !self.sessions.contains_key(&id) {
            match self.restore(id) {
                Ok(Some((report, evicted))) => {
                    restored = Some(report);
                    restore_evictions = evicted;
                }
                Ok(None) => {}
                Err(miss) => return Err(miss),
            }
        }
        let clock = self.clock;
        let model = &self.model;
        let Some(entry) = self.sessions.get_mut(&id) else {
            return Err(if self.evicted.contains_key(&id) {
                StepMiss::Evicted
            } else {
                StepMiss::Unknown
            });
        };
        let before = entry.bytes;
        let result = model.step(&mut entry.session, token);
        let after = entry.session.state_bytes();
        entry.bytes = after;
        entry.last_used = clock;
        // `before` is included in the resident total, so this never underflows.
        self.resident_bytes = self.resident_bytes - before + after;
        let mut evicted = restore_evictions;
        evicted.extend(self.enforce_budget(Some(id)));
        Ok(StepOutcome {
            result,
            evicted,
            restored,
        })
    }

    /// Rehydrate `id` from its spill file if it has one. `Ok(None)`
    /// means there was nothing to restore (unknown or hard-evicted id
    /// — the caller reports the precise miss). A file failing
    /// validation is deleted, the tombstone downgrades to
    /// hard-evicted, and the typed reason surfaces as
    /// [`StepMiss::SpillFailed`].
    fn restore(&mut self, id: u64) -> Result<Option<(RestoreReport, Vec<Eviction>)>, StepMiss> {
        if !self.was_spilled(id) {
            return Ok(None);
        }
        let _restore_span = crate::obs::span("decode.restore");
        let started = std::time::Instant::now();
        let Some(mut tomb) = self.evicted.remove(&id) else {
            return Ok(None);
        };
        let Some(record) = tomb.spill.take() else {
            self.evicted.insert(id, tomb);
            return Ok(None);
        };
        self.spill_order.retain(|&s| s != id);
        self.spilled_bytes = self.spilled_bytes.saturating_sub(record.file_bytes);
        let loaded = spill::read_spill(&record.path, &self.model).and_then(|s| {
            if s.id == id {
                Ok(s)
            } else {
                Err(SpillError::Codec(crate::util::bytes::CodecError::Invalid {
                    what: "session id mismatch",
                }))
            }
        });
        spill::remove_spill(&record.path);
        match loaded {
            Ok(spilled) => {
                self.evicted_order.retain(|&e| e != id);
                let bytes = spilled.session.state_bytes();
                self.resident_bytes += bytes;
                self.sessions.insert(
                    id,
                    Resident {
                        session: spilled.session,
                        last_used: self.clock,
                        bytes,
                        // Keep the trace minted at open: the restored
                        // stream continues the same trace.
                        trace: spilled.trace,
                    },
                );
                crate::obs::recorder::record_event(
                    crate::obs::recorder::EventKind::Restore,
                    spilled.trace,
                    id,
                    bytes,
                );
                let evicted = self.enforce_budget(Some(id));
                Ok(Some((
                    RestoreReport {
                        bytes,
                        elapsed: started.elapsed(),
                    },
                    evicted,
                )))
            }
            Err(err) => {
                // Downgrade to a hard tombstone: the next step (after
                // this error) reports Evicted, and reopening re-prefills.
                self.evicted.insert(id, tomb);
                Err(StepMiss::SpillFailed(err))
            }
        }
    }

    /// Drop a session normally, returning its closing summary. Works
    /// on evicted-or-spilled sessions too: the summary then carries
    /// what was known at eviction time (`evicted: true`) and the spill
    /// file, if any, is cleaned up. A closed session is forgotten —
    /// stepping it again yields [`StepMiss::Unknown`].
    pub fn close(&mut self, id: u64) -> Option<SessionSummary> {
        if let Some(entry) = self.sessions.remove(&id) {
            self.resident_bytes -= entry.bytes;
            return Some(SessionSummary {
                tokens: entry.session.len(),
                branches: entry.session.branches(),
                bytes: entry.bytes,
                promoted_at: entry.session.promoted_at(),
                trace: entry.trace,
                evicted: false,
            });
        }
        let tomb = self.evicted.remove(&id)?;
        self.evicted_order.retain(|&e| e != id);
        if let Some(record) = &tomb.spill {
            self.spill_order.retain(|&s| s != id);
            self.spilled_bytes = self.spilled_bytes.saturating_sub(record.file_bytes);
            spill::remove_spill(&record.path);
        }
        Some(SessionSummary {
            tokens: tomb.tokens,
            branches: tomb.branches,
            bytes: tomb.state_bytes,
            promoted_at: tomb.promoted_at,
            trace: tomb.trace,
            evicted: true,
        })
    }

    /// Per-layer branch occupancy across resident sessions: for each
    /// layer, how many sessions it serves on (KV, recurrent).
    pub fn layer_occupancy(&self) -> (Vec<u64>, Vec<u64>) {
        let n = self.model.config().n_layers;
        let mut kv = vec![0u64; n];
        let mut recurrent = vec![0u64; n];
        for entry in self.sessions.values() {
            for (l, b) in entry.session.branches().iter().enumerate() {
                match b {
                    AttentionVariant::Efficient => recurrent[l] += 1,
                    _ => kv[l] += 1,
                }
            }
        }
        (kv, recurrent)
    }

    fn spill_path(&self, dir: &PathBuf, id: u64) -> PathBuf {
        dir.join(format!("s{}-{id}.spill", self.store_tag))
    }

    fn forget_tombstone(&mut self, id: u64) {
        if let Some(tomb) = self.evicted.remove(&id) {
            self.evicted_order.retain(|&e| e != id);
            if let Some(record) = &tomb.spill {
                self.spill_order.retain(|&s| s != id);
                self.spilled_bytes = self.spilled_bytes.saturating_sub(record.file_bytes);
                spill::remove_spill(&record.path);
            }
        }
    }

    fn record_tombstone(&mut self, id: u64, tomb: Tombstone) {
        let spilled = tomb.spill.is_some();
        if self.evicted.insert(id, tomb).is_none() {
            self.evicted_order.push_back(id);
        }
        if spilled {
            self.spill_order.push_back(id);
        }
        while self.evicted_order.len() > Self::EVICTED_MEMORY {
            let Some(old) = self.evicted_order.pop_front() else {
                break;
            };
            if let Some(aged) = self.evicted.remove(&old) {
                if let Some(record) = &aged.spill {
                    self.spill_order.retain(|&s| s != old);
                    self.spilled_bytes = self.spilled_bytes.saturating_sub(record.file_bytes);
                    spill::remove_spill(&record.path);
                }
            }
        }
    }

    /// Second-level eviction: drop oldest spill files until `needed`
    /// extra bytes fit the spill budget. Dropped sessions downgrade to
    /// hard tombstones (their next step is `Evicted`).
    fn make_spill_room(&mut self, needed: u64) -> bool {
        if needed > self.spill_budget {
            return false;
        }
        while self.spilled_bytes + needed > self.spill_budget {
            let Some(old) = self.spill_order.pop_front() else {
                break;
            };
            if let Some(tomb) = self.evicted.get_mut(&old) {
                if let Some(record) = tomb.spill.take() {
                    self.spilled_bytes = self.spilled_bytes.saturating_sub(record.file_bytes);
                    spill::remove_spill(&record.path);
                }
            }
        }
        self.spilled_bytes + needed <= self.spill_budget
    }

    /// Park an evicted session's state on disk. Returns the spill
    /// record, or `None` when the tier is disabled, the file cannot
    /// fit the budget, or the write fails (hard eviction).
    fn try_spill(&mut self, id: u64, trace: u64, session: &ModelSession) -> Option<SpillRecord> {
        let dir = self.spill_dir.clone()?;
        let needed = spill::spill_file_size(session);
        if !self.make_spill_room(needed) {
            return None;
        }
        let path = self.spill_path(&dir, id);
        match spill::write_spill(&path, id, trace, session) {
            Ok(file_bytes) => {
                self.spilled_bytes += file_bytes;
                Some(SpillRecord { path, file_bytes })
            }
            Err(_) => None,
        }
    }

    /// Evict LRU sessions until both the byte budget and the session
    /// cap hold; each victim is spilled to disk when the tier allows
    /// it. The session named by `protect` (the one being operated on)
    /// is never evicted.
    fn enforce_budget(&mut self, protect: Option<u64>) -> Vec<Eviction> {
        let mut evicted = Vec::new();
        loop {
            let over_bytes = self.resident_bytes > self.cfg.max_session_bytes;
            let over_count = self.sessions.len() > self.cfg.max_sessions;
            if !over_bytes && !over_count {
                break;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(id, _)| Some(**id) != protect)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(id, _)| *id);
            let Some(victim) = victim else {
                break; // only the protected session remains
            };
            // The victim id was taken from `sessions` under `&mut self`,
            // so the remove can only miss if that invariant broke — stop
            // evicting rather than panic mid-request.
            let Some(gone) = self.sessions.remove(&victim) else {
                break;
            };
            self.resident_bytes -= gone.bytes;
            let record = self.try_spill(victim, gone.trace, &gone.session);
            let spilled = record.is_some();
            let (kind, detail) = if spilled {
                (crate::obs::recorder::EventKind::Spill, gone.bytes)
            } else {
                (crate::obs::recorder::EventKind::Evict, gone.bytes)
            };
            crate::obs::recorder::record_event(kind, gone.trace, victim, detail);
            self.record_tombstone(
                victim,
                Tombstone {
                    trace: gone.trace,
                    tokens: gone.session.len(),
                    branches: gone.session.branches(),
                    promoted_at: gone.session.promoted_at(),
                    state_bytes: gone.bytes,
                    spill: record,
                },
            );
            evicted.push(Eviction {
                id: victim,
                bytes: gone.bytes,
                spilled,
            });
        }
        evicted
    }
}

impl Drop for SessionStore {
    /// Spill files are per-store scratch state; remove them so a
    /// dropped engine leaves no disk residue.
    fn drop(&mut self) {
        for tomb in self.evicted.values() {
            if let Some(record) = &tomb.spill {
                spill::remove_spill(&record.path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> DecodeConfig {
        DecodeConfig {
            heads: 1,
            n_layers: 1,
            d_ff: 16,
            ..DecodeConfig::default()
        }
    }

    fn spill_dir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ts-store-test-{}-{name}", std::process::id()))
    }

    fn token(d_model: usize, seed: u64) -> Tensor {
        Tensor::randn(&[1, d_model], seed)
    }

    fn ids(evicted: &[Eviction]) -> Vec<u64> {
        evicted.iter().map(|e| e.id).collect()
    }

    #[test]
    fn store_evicts_lru_under_byte_budget() {
        let d = 8usize;
        let cfg = DecodeConfig {
            // Room for roughly two single-layer KV sessions of ~12 tokens.
            max_session_bytes: 2 * 12 * 2 * d as u64 * 4,
            max_sessions: 16,
            ..small_cfg()
        };
        let mut store =
            SessionStore::new(cfg, d, Selector::analytical(), Some(AttentionVariant::Direct));
        let t = token(d, 7);
        store.open(1);
        store.open(2);
        store.open(3);
        let mut all_evicted = Vec::new();
        for _ in 0..12 {
            for id in [1u64, 2, 3] {
                if store.contains(id) {
                    let out = store.step(id, &t).unwrap();
                    all_evicted.extend(out.evicted);
                }
            }
        }
        assert!(!all_evicted.is_empty(), "budget never triggered eviction");
        assert!(store.resident_bytes() <= store.config().max_session_bytes);
        assert!(all_evicted.iter().all(|e| !e.spilled), "spill disabled");
        // Evicted sessions miss with the typed re-prefill error.
        let gone = all_evicted[0].id;
        assert_eq!(store.step(gone, &t).unwrap_err(), StepMiss::Evicted);
    }

    #[test]
    fn store_caps_session_count() {
        let cfg = DecodeConfig {
            max_sessions: 2,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        assert!(store.open(1).is_empty());
        assert!(store.open(2).is_empty());
        let evicted = store.open(3);
        assert_eq!(ids(&evicted), vec![1], "oldest session evicted");
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn lru_order_follows_use_not_creation() {
        let cfg = DecodeConfig {
            max_sessions: 2,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        let t = token(4, 9);
        store.open(1);
        store.open(2);
        store.step(1, &t).unwrap(); // 1 is now most recent
        let evicted = store.open(3);
        assert_eq!(ids(&evicted), vec![2]);
        assert!(store.contains(1) && store.contains(3));
    }

    #[test]
    fn forced_direct_never_promotes() {
        let mut store = SessionStore::new(
            small_cfg(),
            2, // crossover N0(2) is tiny — would promote immediately
            Selector::analytical(),
            Some(AttentionVariant::Direct),
        );
        let t = token(2, 3);
        store.open(5);
        for _ in 0..32 {
            let out = store.step(5, &t).unwrap();
            for ls in &out.result.layers {
                assert_eq!(ls.branch, AttentionVariant::Direct);
                assert!(!ls.promoted);
            }
        }
    }

    #[test]
    fn forced_efficient_starts_recurrent() {
        let mut store = SessionStore::new(
            small_cfg(),
            16,
            Selector::analytical(),
            Some(AttentionVariant::Efficient),
        );
        let t = token(16, 4);
        store.open(5);
        let out = store.step(5, &t).unwrap();
        for ls in &out.result.layers {
            assert_eq!(ls.branch, AttentionVariant::Efficient);
            assert!(!ls.promoted, "no promotion event when born recurrent");
        }
    }

    #[test]
    fn close_reports_summary_and_frees_bytes() {
        let cfg = DecodeConfig {
            heads: 2,
            n_layers: 2,
            d_ff: 16,
            ..DecodeConfig::default()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        let t = token(8, 11);
        store.open(9);
        for _ in 0..3 {
            store.step(9, &t).unwrap();
        }
        let summary = store.close(9).unwrap();
        assert_eq!(summary.tokens, 3);
        assert_eq!(summary.branches.len(), 2);
        assert_eq!(summary.promoted_at.len(), 2);
        assert!(!summary.evicted);
        assert_eq!(store.resident_bytes(), 0);
        assert!(store.close(9).is_none());
        // Closed ≠ evicted: the next step is Unknown, not Evicted.
        assert_eq!(store.step(9, &t).unwrap_err(), StepMiss::Unknown);
    }

    #[test]
    fn unknown_session_misses_as_unknown() {
        let mut store = SessionStore::new(small_cfg(), 4, Selector::analytical(), None);
        assert_eq!(store.step(99, &token(4, 1)).unwrap_err(), StepMiss::Unknown);
    }

    #[test]
    fn reopen_clears_eviction_tombstone() {
        let cfg = DecodeConfig {
            max_sessions: 1,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        store.open(1);
        let evicted = store.open(2);
        assert_eq!(ids(&evicted), vec![1]);
        assert!(store.was_evicted(1));
        let t = token(4, 2);
        assert_eq!(store.step(1, &t).unwrap_err(), StepMiss::Evicted);
        store.open(1); // re-prefill path: reopen after eviction
        assert!(!store.was_evicted(1));
        assert!(store.step(1, &t).is_ok());
    }

    #[test]
    fn layer_occupancy_counts_branches() {
        let cfg = DecodeConfig {
            n_layers: 2,
            d_ff: 16,
            ..small_cfg()
        };
        let mut store = SessionStore::new(
            cfg,
            4,
            Selector::analytical(),
            Some(AttentionVariant::Direct),
        );
        store.open(1);
        store.open(2);
        let (kv, recurrent) = store.layer_occupancy();
        assert_eq!(kv, vec![2, 2]);
        assert_eq!(recurrent, vec![0, 0]);
    }

    #[test]
    fn spilled_session_restores_transparently() {
        let dir = spill_dir("restore");
        let cfg = DecodeConfig {
            max_sessions: 1,
            spill: crate::decode::SpillConfig::enabled_in(dir.clone()),
            ..small_cfg()
        };
        let d = 4usize;
        let mut store = SessionStore::new(cfg, d, Selector::analytical(), None);
        let t = token(d, 2);
        store.open(1);
        let trace1 = store.trace_of(1).unwrap();
        for _ in 0..5 {
            store.step(1, &t).unwrap();
        }
        let evicted = store.open(2);
        assert_eq!(evicted.len(), 1);
        assert!(evicted[0].spilled, "spill tier should have caught the victim");
        assert!(store.was_spilled(1));
        assert_eq!(store.spilled_sessions(), 1);
        assert!(store.spilled_bytes() > 0);
        assert_eq!(store.trace_of(1), Some(trace1), "trace survives the spill");

        // The next step restores transparently and evicts session 2.
        let out = store.step(1, &t).unwrap();
        let report = out.restored.expect("step should report the restore");
        assert!(report.bytes > 0);
        assert_eq!(out.result.len, 6, "restored stream continues at its length");
        assert_eq!(ids(&out.evicted), vec![2]);
        assert!(!store.was_evicted(1));
        assert_eq!(store.spilled_sessions(), 1, "victim 2 spilled in turn");
        assert_eq!(store.trace_of(1), Some(trace1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restored_stream_is_bit_exact_with_uninterrupted() {
        let dir = spill_dir("bitexact");
        let d = 4usize;
        let spill_cfg = DecodeConfig {
            max_sessions: 1,
            spill: crate::decode::SpillConfig::enabled_in(dir.clone()),
            ..small_cfg()
        };
        let big_cfg = small_cfg();
        let mut spilled = SessionStore::new(spill_cfg, d, Selector::analytical(), None);
        let mut reference = SessionStore::new(big_cfg, d, Selector::analytical(), None);
        spilled.open(1);
        reference.open(1);
        for s in 0..12u64 {
            let t = token(d, 100 + s);
            if s == 6 {
                spilled.open(2); // force the spill mid-stream
            }
            let a = spilled.step(1, &t).unwrap();
            let b = reference.step(1, &t).unwrap();
            let eq = a
                .result
                .output
                .iter()
                .zip(&b.result.output)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "step {} diverged after spill round trip", s + 1);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_budget_exhaustion_hard_evicts_oldest() {
        let dir = spill_dir("budget");
        let d = 4usize;
        let t = token(d, 3);
        // Measure what one 1-step session's spill file costs, then set
        // the budget so exactly one such file fits — not two.
        let probe_model = StreamingModel::new(ModelConfig::from_decode(&small_cfg(), d));
        let mut probe = ModelSession::new(&probe_model, &Selector::analytical(), None);
        probe_model.step(&mut probe, &t);
        let one_file = super::spill::spill_file_size(&probe);
        let mut spill = crate::decode::SpillConfig::enabled_in(dir.clone());
        spill.max_bytes = one_file + one_file / 2;
        let cfg = DecodeConfig {
            max_sessions: 1,
            spill,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, d, Selector::analytical(), None);
        store.open(1);
        store.step(1, &t).unwrap();
        store.open(2); // spills 1
        assert!(store.was_spilled(1));
        store.step(2, &t).unwrap();
        store.open(3); // spills 2, which needs room: 1's file is dropped
        assert!(store.was_spilled(2));
        assert!(store.was_evicted(1));
        assert!(!store.was_spilled(1), "oldest spill dropped for room");
        assert_eq!(store.step(1, &t).unwrap_err(), StepMiss::Evicted);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_file_fails_typed_then_evicted() {
        let dir = spill_dir("corrupt");
        let cfg = DecodeConfig {
            max_sessions: 1,
            spill: crate::decode::SpillConfig::enabled_in(dir.clone()),
            ..small_cfg()
        };
        let d = 4usize;
        let mut store = SessionStore::new(cfg, d, Selector::analytical(), None);
        let t = token(d, 5);
        store.open(1);
        store.step(1, &t).unwrap();
        store.open(2);
        assert!(store.was_spilled(1));
        // Flip a payload byte in the (single) spill file.
        let entries: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert_eq!(entries.len(), 1);
        let mut bytes = std::fs::read(&entries[0]).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&entries[0], &bytes).unwrap();

        let err = store.step(1, &t).unwrap_err();
        assert!(
            matches!(
                err,
                StepMiss::SpillFailed(SpillError::ChecksumMismatch { .. })
            ),
            "got {err:?}"
        );
        // The file is gone and the session downgraded to hard-evicted.
        assert!(!store.was_spilled(1));
        assert_eq!(store.spilled_sessions(), 0);
        assert_eq!(store.step(1, &t).unwrap_err(), StepMiss::Evicted);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn close_on_spilled_session_reports_and_cleans_up() {
        let dir = spill_dir("close");
        let cfg = DecodeConfig {
            max_sessions: 1,
            spill: crate::decode::SpillConfig::enabled_in(dir.clone()),
            ..small_cfg()
        };
        let d = 4usize;
        let mut store = SessionStore::new(cfg, d, Selector::analytical(), None);
        let t = token(d, 6);
        store.open(1);
        for _ in 0..4 {
            store.step(1, &t).unwrap();
        }
        let trace1 = store.trace_of(1).unwrap();
        store.open(2);
        assert!(store.was_spilled(1));
        let summary = store.close(1).expect("close must work on a spilled session");
        assert!(summary.evicted);
        assert_eq!(summary.tokens, 4);
        assert_eq!(summary.trace, trace1);
        assert_eq!(summary.branches.len(), 1);
        assert_eq!(store.spilled_sessions(), 0, "spill file cleaned up");
        assert_eq!(store.spilled_bytes(), 0);
        // Closed is forgotten entirely.
        assert_eq!(store.step(1, &t).unwrap_err(), StepMiss::Unknown);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn close_on_hard_evicted_session_reports_known_state() {
        let cfg = DecodeConfig {
            max_sessions: 1,
            ..small_cfg()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        let t = token(4, 8);
        store.open(1);
        store.step(1, &t).unwrap();
        store.step(1, &t).unwrap();
        store.open(2); // hard-evicts 1 (spill disabled)
        let summary = store.close(1).expect("close must work on an evicted session");
        assert!(summary.evicted);
        assert_eq!(summary.tokens, 2);
        assert!(store.close(1).is_none());
    }
}

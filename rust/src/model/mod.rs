//! Whole-model streaming decode: a transformer of TaylorShift blocks
//! that serves one token at a time from per-layer resident state.
//!
//! PR 6's `decode/` subsystem streams a *single* attention module; the
//! paper's efficiency story only pays off when the entire model
//! streams. Following the linear-attention-as-RNN decomposition
//! (Katharopoulos et al., "Transformers are RNNs"), each [`Block`]
//! (pre-LN → TaylorShift multi-head attention → residual → MLP →
//! residual) owns its own decode state and the [`StreamingModel`]
//! threads one token through all L blocks per step.
//!
//! ## Per-layer crossover math
//!
//! Every layer sees every token, so all layer states share one prefix
//! length N — but each layer holds an independent
//! [`crate::decode::DecodeSession`] with its own branch and promotion
//! threshold:
//!
//! * below the selector's crossover N₀(d) a layer serves from a
//!   `KvCache` — O(N·d) per token per head, O(N·d) state;
//! * at N ≥ N₀(d) the layer is **promoted**: its cached (normalized
//!   key, value) pairs are replayed once (O(N·d³)) into the Taylor
//!   moments of a `RecurrentState`, after which each token costs
//!   O(d³) per head — flat in N.
//!
//! With a shared head dimension the analytical threshold is the same
//! for every layer, and layers promote on the same step; forced
//! variants, per-layer thresholds (tests/benches), or future per-layer
//! head dims make them cross independently — the state stack supports
//! both.
//!
//! ## Promotion invariants
//!
//! Both branches compute the same attention function, so the output
//! stream is continuous across any layer's switch. The batch mirror
//! [`crate::attention::causal::causal_taylor`] replicates the state
//! machines' arithmetic exactly, which is what lets the parity tests
//! demand streaming ≡ batch at every prefix length, including streams
//! where only a strict subset of layers promotes mid-stream. A
//! promoted layer records the prefix length at which it switched
//! (`promoted_at`), and a promotion replays exactly the tokens cached
//! *before* the promoting token — the token that crosses the threshold
//! is absorbed raw into the fresh moments.
//!
//! The serving integration lives in [`SessionStore`] (LRU over
//! [`ModelSession`]s, byte accounting summed across layers) and
//! `coordinator/engine.rs` (`submit_stream` / `decode_step` /
//! `close_stream`).

pub mod block;
pub mod spill;
pub mod store;
pub mod streaming;

pub use block::{layer_norm, Block};
pub use spill::{SpillError, SpilledSession};
pub use store::{Eviction, RestoreReport, SessionStore, SessionSummary, StepMiss, StepOutcome};
pub use streaming::{LayerStep, ModelSession, ModelStepResult, StreamingModel};

use crate::decode::DecodeConfig;

/// Architecture of the streaming transformer.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Transformer blocks the token passes through.
    pub n_layers: usize,
    /// Attention heads per block.
    pub heads: usize,
    /// Per-head dimension (the selector's `d`).
    pub head_dim: usize,
    /// Hidden width of each block's MLP.
    pub d_ff: usize,
    /// Per-layer attention temperature, length `n_layers`.
    pub taus: Vec<f32>,
    /// Weight-init seed (deterministic model).
    pub seed: u64,
}

impl ModelConfig {
    /// Model width: `heads · head_dim`.
    pub fn d_model(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Derive the architecture from the engine's decode config. An
    /// empty `layer_taus` broadcasts the scalar `tau` to every layer.
    pub fn from_decode(decode: &DecodeConfig, head_dim: usize) -> Self {
        let taus = if decode.layer_taus.is_empty() {
            vec![decode.tau; decode.n_layers]
        } else {
            assert_eq!(
                decode.layer_taus.len(),
                decode.n_layers,
                "layer_taus length must equal n_layers"
            );
            decode.layer_taus.clone()
        };
        Self {
            n_layers: decode.n_layers,
            heads: decode.heads,
            head_dim,
            d_ff: decode.d_ff,
            taus,
            seed: decode.model_seed,
        }
    }
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig::from_decode(&DecodeConfig::default(), 16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_decode_broadcasts_tau() {
        let decode = DecodeConfig {
            heads: 2,
            tau: 1.25,
            n_layers: 3,
            ..DecodeConfig::default()
        };
        let cfg = ModelConfig::from_decode(&decode, 8);
        assert_eq!(cfg.d_model(), 16);
        assert_eq!(cfg.taus, vec![1.25; 3]);
        assert_eq!(cfg.seed, decode.model_seed);
    }

    #[test]
    fn from_decode_takes_per_layer_taus() {
        let decode = DecodeConfig {
            n_layers: 2,
            layer_taus: vec![0.5, 2.0],
            ..DecodeConfig::default()
        };
        let cfg = ModelConfig::from_decode(&decode, 4);
        assert_eq!(cfg.taus, vec![0.5, 2.0]);
    }

    #[test]
    #[should_panic(expected = "layer_taus length must equal n_layers")]
    fn mismatched_layer_taus_panic() {
        let decode = DecodeConfig {
            n_layers: 3,
            layer_taus: vec![1.0],
            ..DecodeConfig::default()
        };
        let _ = ModelConfig::from_decode(&decode, 4);
    }
}

//! [`StreamingModel`] (the weights) and [`ModelSession`] (one stream's
//! per-layer decode state stack).
//!
//! The model is a stack of [`Block`]s with deterministic seeded
//! weights; `forward_batch` is the whole-sequence reference and `step`
//! threads one `[1, d_model]` token through every block against the
//! session's per-layer [`DecodeSession`]s. Layer `l`'s decode state may
//! sit on either branch independently of the others — the session
//! carries one threshold per layer.

use crate::attention::selector::Selector;
use crate::attention::AttentionVariant;
use crate::decode::DecodeSession;
use crate::tensor::Tensor;
use crate::util::bytes::{ByteReader, ByteWriter, CodecError};

use super::block::Block;
use super::ModelConfig;

/// What one layer did during a model step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerStep {
    /// Branch that served this layer's attention.
    pub branch: AttentionVariant,
    /// True iff this step triggered this layer's KV→recurrent switch.
    pub promoted: bool,
}

/// Result of threading one token through all layers.
#[derive(Clone, Debug)]
pub struct ModelStepResult {
    /// Final-block output row, length `d_model`.
    pub output: Vec<f32>,
    /// Per-layer branch/promotion records, length `n_layers`.
    pub layers: Vec<LayerStep>,
    /// Prefix length after this step.
    pub len: usize,
}

/// One stream's state: a per-layer stack of decode sessions plus the
/// promotion threshold each layer watches.
pub struct ModelSession {
    layers: Vec<DecodeSession>,
    thresholds: Vec<Option<f64>>,
    len: usize,
}

impl ModelSession {
    /// Open a session under the engine's policy: `forced` pins every
    /// layer to one branch (`Direct` → KV forever, `Efficient` → born
    /// recurrent); otherwise each layer starts on the branch the
    /// selector picks for a length-1 prefix and promotes at the
    /// selector's crossover for the model's head dimension.
    pub fn new(model: &StreamingModel, selector: &Selector, forced: Option<AttentionVariant>) -> Self {
        let head_dim = model.config().head_dim;
        let start_recurrent = match forced {
            Some(AttentionVariant::Efficient) => true,
            Some(AttentionVariant::Direct) => false,
            _ => selector.select(1, head_dim) == AttentionVariant::Efficient,
        };
        let threshold = match forced {
            Some(AttentionVariant::Direct) | Some(AttentionVariant::Efficient) => None,
            _ => Some(selector.crossover(head_dim)),
        };
        let n = model.config().n_layers;
        Self::with_thresholds(model, &vec![start_recurrent; n], vec![threshold; n])
    }

    /// Open a session with explicit per-layer starting branches and
    /// promotion thresholds (tests/benches force layers to cross at
    /// chosen steps).
    pub fn with_thresholds(
        model: &StreamingModel,
        start_recurrent: &[bool],
        thresholds: Vec<Option<f64>>,
    ) -> Self {
        let cfg = model.config();
        assert_eq!(start_recurrent.len(), cfg.n_layers, "start_recurrent length mismatch");
        assert_eq!(thresholds.len(), cfg.n_layers, "thresholds length mismatch");
        let layers = (0..cfg.n_layers)
            .map(|l| DecodeSession::new(cfg.heads, cfg.head_dim, cfg.taus[l], start_recurrent[l]))
            .collect();
        Self {
            layers,
            thresholds,
            len: 0,
        }
    }

    /// Tokens streamed so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Resident bytes summed across every layer's state.
    pub fn state_bytes(&self) -> u64 {
        self.layers.iter().map(DecodeSession::state_bytes).sum()
    }

    /// Branch currently serving each layer.
    pub fn branches(&self) -> Vec<AttentionVariant> {
        self.layers.iter().map(DecodeSession::branch).collect()
    }

    /// Per-layer promotion points (prefix length including the
    /// promoting token), `None` for layers still on KV.
    pub fn promoted_at(&self) -> Vec<Option<usize>> {
        self.layers.iter().map(DecodeSession::promoted_at).collect()
    }

    /// Serialize the whole per-layer state stack bit-exactly (the
    /// spill payload body): stream length, then each layer's threshold
    /// and decode state.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.len as u64);
        w.put_u32(self.layers.len() as u32);
        for (layer, threshold) in self.layers.iter().zip(&self.thresholds) {
            match threshold {
                Some(t) => {
                    w.put_u8(1);
                    w.put_f64(*t);
                }
                None => w.put_u8(0),
            }
            layer.encode(w);
        }
    }

    /// Inverse of [`ModelSession::encode`], validated against the
    /// model the session will be stepped with: layer count, heads, and
    /// head dim must all match or the restore is rejected.
    pub fn decode(r: &mut ByteReader<'_>, model: &StreamingModel) -> Result<Self, CodecError> {
        let cfg = model.config();
        let len = r.get_u64()? as usize;
        let n_layers = r.get_u32()? as usize;
        if n_layers != cfg.n_layers {
            return Err(CodecError::Invalid { what: "layer count" });
        }
        let mut layers = Vec::with_capacity(n_layers);
        let mut thresholds = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let threshold = match r.get_u8()? {
                0 => None,
                1 => Some(r.get_f64()?),
                tag => return Err(CodecError::BadTag { what: "threshold", tag }),
            };
            let layer = DecodeSession::decode(r)?;
            if layer.heads() != cfg.heads || layer.head_dim() != cfg.head_dim {
                return Err(CodecError::Invalid { what: "layer shape vs model" });
            }
            if layer.len() != len {
                return Err(CodecError::Invalid { what: "layer length vs stream" });
            }
            thresholds.push(threshold);
            layers.push(layer);
        }
        Ok(Self {
            layers,
            thresholds,
            len,
        })
    }
}

/// A deterministic stack of TaylorShift transformer blocks.
pub struct StreamingModel {
    cfg: ModelConfig,
    blocks: Vec<Block>,
}

impl StreamingModel {
    pub fn new(cfg: ModelConfig) -> Self {
        assert!(cfg.n_layers > 0, "model needs at least one layer");
        assert_eq!(cfg.taus.len(), cfg.n_layers, "taus length must equal n_layers");
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                Block::new(
                    cfg.heads,
                    cfg.head_dim,
                    cfg.d_ff,
                    cfg.taus[l],
                    cfg.seed.wrapping_add(1000 * l as u64),
                )
            })
            .collect();
        Self { cfg, blocks }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn d_model(&self) -> usize {
        self.cfg.d_model()
    }

    /// Whole-sequence reference forward pass. `promotions[l]` is the
    /// prefix length at which layer `l`'s decode state promotes
    /// (`None` = stays KV), forwarded to each block's causal mirror so
    /// this matches a stream whose layers cross at those exact steps.
    pub fn forward_batch(&self, x: &Tensor, promotions: &[Option<usize>]) -> Tensor {
        assert_eq!(promotions.len(), self.blocks.len(), "one promotion point per layer");
        let mut h = x.clone();
        for (block, &p) in self.blocks.iter().zip(promotions) {
            h = block.forward_batch(&h, p);
        }
        h
    }

    /// Thread one `[1, d_model]` token through all layers against the
    /// session's state stack.
    pub fn step(&self, session: &mut ModelSession, token: &Tensor) -> ModelStepResult {
        assert_eq!(
            token.shape(),
            &[1, self.d_model()],
            "token must be [1, d_model={}]",
            self.d_model()
        );
        assert_eq!(
            session.layers.len(),
            self.blocks.len(),
            "session layer stack does not match this model"
        );
        let model_span = crate::obs::span("model.step");
        let mut h = token.clone();
        let mut layers = Vec::with_capacity(self.blocks.len());
        for (l, block) in self.blocks.iter().enumerate() {
            // Clamp so a (hypothetical) very deep model cannot collide
            // with the NO_LAYER sentinel.
            let layer_tag = l.min(u16::MAX as usize - 1) as u16;
            let layer_span = crate::obs::span_layer("model.block_step", layer_tag);
            let (out, r) = block.stream_step(&h, &mut session.layers[l], session.thresholds[l]);
            drop(layer_span);
            layers.push(LayerStep {
                branch: r.branch,
                promoted: r.promoted,
            });
            h = out;
        }
        drop(model_span);
        session.len += 1;
        ModelStepResult {
            output: h.into_data(),
            layers,
            len: session.len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::DecodeConfig;

    fn small_model(n_layers: usize) -> StreamingModel {
        let decode = DecodeConfig {
            heads: 2,
            n_layers,
            d_ff: 24,
            ..DecodeConfig::default()
        };
        StreamingModel::new(ModelConfig::from_decode(&decode, 4))
    }

    /// Layers promoting at different steps must still match the batch
    /// reference bit-for-bit at every prefix.
    #[test]
    fn streaming_matches_batch_with_mixed_promotions() {
        let model = small_model(3);
        let n = 14usize;
        // Layer 0 promotes at 4, layer 2 at 9, layer 1 never.
        let promotions = [Some(4), None, Some(9)];
        let x = Tensor::randn(&[n, model.d_model()], 555);
        let batch = model.forward_batch(&x, &promotions);
        let thresholds = promotions.iter().map(|p| p.map(|v| v as f64)).collect();
        let mut session = ModelSession::with_thresholds(&model, &[false; 3], thresholds);
        for t in 0..n {
            let token = Tensor::new(&[1, model.d_model()], x.row(t).to_vec());
            let r = model.step(&mut session, &token);
            assert_eq!(r.len, t + 1);
            assert_eq!(r.output.as_slice(), batch.row(t), "prefix {} diverged", t + 1);
            for (l, ls) in r.layers.iter().enumerate() {
                assert_eq!(
                    ls.promoted,
                    promotions[l] == Some(t + 1),
                    "layer {l} promotion flag at step {}",
                    t + 1
                );
            }
        }
        assert_eq!(session.promoted_at(), promotions.to_vec());
        assert_eq!(
            session.branches(),
            vec![
                AttentionVariant::Efficient,
                AttentionVariant::Direct,
                AttentionVariant::Efficient
            ]
        );
    }

    #[test]
    fn state_bytes_sum_layers() {
        let model = small_model(2);
        let mut session = ModelSession::with_thresholds(&model, &[false, false], vec![None, None]);
        let fresh = session.state_bytes();
        assert_eq!(
            fresh,
            session.layers.iter().map(DecodeSession::state_bytes).sum::<u64>()
        );
        let token = Tensor::randn(&[1, model.d_model()], 8);
        model.step(&mut session, &token);
        assert!(session.state_bytes() > fresh, "KV layers grow with tokens");
        assert_eq!(session.len(), 1);
    }

    #[test]
    fn session_encode_decode_roundtrip_is_bit_exact() {
        let model = small_model(2);
        let thresholds = vec![Some(3.0f64), None];
        let mut session = ModelSession::with_thresholds(&model, &[false, false], thresholds);
        let x = Tensor::randn(&[9, model.d_model()], 777);
        for t in 0..6 {
            let token = Tensor::new(&[1, model.d_model()], x.row(t).to_vec());
            model.step(&mut session, &token);
        }
        let mut w = crate::util::bytes::ByteWriter::new();
        session.encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bytes::ByteReader::new(&bytes);
        let mut back = ModelSession::decode(&mut r, &model).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.len(), session.len());
        assert_eq!(back.branches(), session.branches());
        assert_eq!(back.promoted_at(), session.promoted_at());
        assert_eq!(back.thresholds, session.thresholds);
        for t in 6..9 {
            let token = Tensor::new(&[1, model.d_model()], x.row(t).to_vec());
            let a = model.step(&mut session, &token);
            let b = model.step(&mut back, &token);
            let eq = a
                .output
                .iter()
                .zip(&b.output)
                .all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(eq, "step {} diverged after restore", t + 1);
        }
    }

    #[test]
    fn session_decode_rejects_wrong_model_shape() {
        let model = small_model(2);
        let mut session =
            ModelSession::with_thresholds(&model, &[false, false], vec![None, None]);
        let token = Tensor::randn(&[1, model.d_model()], 5);
        model.step(&mut session, &token);
        let mut w = crate::util::bytes::ByteWriter::new();
        session.encode(&mut w);
        let bytes = w.into_bytes();
        let other = small_model(3);
        let mut r = crate::util::bytes::ByteReader::new(&bytes);
        assert!(ModelSession::decode(&mut r, &other).is_err());
    }

    #[test]
    fn selector_policy_broadcasts_to_layers() {
        let model = small_model(2);
        // Forced Direct: all layers KV, no thresholds.
        let s = ModelSession::new(&model, &Selector::analytical(), Some(AttentionVariant::Direct));
        assert_eq!(s.branches(), vec![AttentionVariant::Direct; 2]);
        assert_eq!(s.thresholds, vec![None, None]);
        // Forced Efficient: born recurrent everywhere.
        let s = ModelSession::new(&model, &Selector::analytical(), Some(AttentionVariant::Efficient));
        assert_eq!(s.branches(), vec![AttentionVariant::Efficient; 2]);
        // Selector policy: thresholds armed with the d-specific crossover.
        let sel = Selector::analytical();
        let s = ModelSession::new(&model, &sel, None);
        let want = sel.crossover(model.config().head_dim);
        assert_eq!(s.thresholds, vec![Some(want); 2]);
    }
}

//! The training loop over a `*_train_b*` artifact.
//!
//! Artifact signature (manifest order):
//!   inputs  = params… ‖ m… ‖ v… ‖ step ‖ tokens ‖ labels
//!   outputs = params… ‖ m… ‖ v… ‖ loss ‖ acc
//!
//! The driver keeps the P/M/V state as host literals and feeds fresh
//! batches from a task generator each step. (At our model scale the
//! host round-trip is ~1 MB/step; §Perf discusses the device-resident
//! alternative.)

use crate::data::batch::generate_batch;
use crate::data::TaskGenerator;
use crate::runtime::{literal, ArtifactKind, Executable, Registry};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Per-step record.
#[derive(Clone, Copy, Debug)]
pub struct TrainStats {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub step_time_s: f64,
}

/// Summary of a finished run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub history: Vec<TrainStats>,
    pub final_loss: f32,
    pub final_acc: f32,
    pub steps_per_s: f64,
    /// Eval metrics if an eval artifact was attached: (loss, acc).
    pub eval: Option<(f32, f32)>,
}

impl TrainReport {
    /// Smoothed loss over the last `k` recorded steps.
    pub fn tail_loss(&self, k: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(k)..];
        tail.iter().map(|s| s.loss).sum::<f32>() / tail.len().max(1) as f32
    }
}

/// Drives one train-step executable.
pub struct TrainDriver {
    exe: Arc<Executable>,
    eval_exe: Option<Arc<Executable>>,
    /// params ‖ m ‖ v as literals, in artifact input order.
    state: Vec<xla::Literal>,
    n_leaves: usize,
    batch: usize,
    seq_len: usize,
    step: usize,
}

impl TrainDriver {
    /// Load a train artifact and its initial parameters; optimizer
    /// moments start at zero.
    pub fn new(registry: &Registry, name: &str) -> Result<Self> {
        let exe = registry.load(name)?;
        if exe.kind != ArtifactKind::Train {
            bail!("{name} is not a train artifact");
        }
        let params = registry.load_params(name)?;
        let n_leaves = exe.io.params.len();
        if params.len() != n_leaves {
            bail!("params blob mismatch");
        }
        let mut state = Vec::with_capacity(3 * n_leaves);
        for t in &params {
            state.push(literal::tensor_to_literal(t)?);
        }
        for t in &params {
            state.push(literal::tensor_to_literal(&Tensor::zeros(t.shape()))?);
        }
        for t in &params {
            state.push(literal::tensor_to_literal(&Tensor::zeros(t.shape()))?);
        }
        let batch = exe.batch.context("train artifact missing batch")?;
        let seq_len = exe.seq_len.context("train artifact missing seq_len")?;
        Ok(Self {
            exe,
            eval_exe: None,
            state,
            n_leaves,
            batch,
            seq_len,
            step: 0,
        })
    }

    /// Attach an eval artifact (same model family) for held-out metrics.
    pub fn with_eval(mut self, registry: &Registry, name: &str) -> Result<Self> {
        let exe = registry.load(name)?;
        if exe.kind != ArtifactKind::Eval {
            bail!("{name} is not an eval artifact");
        }
        self.eval_exe = Some(exe);
        Ok(self)
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// One optimization step on the given batch (must match the
    /// artifact's (B, N) shape).
    pub fn step_on(&mut self, tokens: &[Vec<i32>], labels: &[i32]) -> Result<TrainStats> {
        if tokens.len() != self.batch || labels.len() != self.batch {
            bail!(
                "batch shape mismatch: got {}x{}, artifact wants {}x{}",
                tokens.len(),
                tokens.first().map(|r| r.len()).unwrap_or(0),
                self.batch,
                self.seq_len
            );
        }
        let t0 = Instant::now();
        let mut inputs = Vec::with_capacity(self.state.len() + 3);
        // State literals move into the call; they are replaced by the
        // outputs below (true state round-trip, no copies kept).
        inputs.append(&mut self.state);
        inputs.push(literal::scalar_i32(self.step as i32));
        inputs.push(literal::tokens_to_literal(tokens)?);
        inputs.push(literal::labels_to_literal(labels));
        let mut outputs = self.exe.run(&inputs)?;
        if outputs.len() != 3 * self.n_leaves + 2 {
            bail!(
                "train step returned {} outputs, expected {}",
                outputs.len(),
                3 * self.n_leaves + 2
            );
        }
        let acc = literal::literal_to_f32(&outputs.pop().unwrap())?;
        let loss = literal::literal_to_f32(&outputs.pop().unwrap())?;
        self.state = outputs;
        self.step += 1;
        Ok(TrainStats {
            step: self.step,
            loss,
            acc,
            step_time_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Train `steps` steps on freshly-generated data.
    pub fn run<G: TaskGenerator>(
        &mut self,
        gen: &G,
        rng: &mut Pcg64,
        steps: usize,
        mut on_step: impl FnMut(&TrainStats),
    ) -> Result<TrainReport> {
        let mut history = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for _ in 0..steps {
            let batch = generate_batch(gen, rng, self.batch, self.seq_len);
            let stats = self.step_on(&batch.tokens, &batch.labels)?;
            on_step(&stats);
            history.push(stats);
        }
        let wall = t0.elapsed().as_secs_f64();
        let eval = match &self.eval_exe {
            Some(_) => Some(self.evaluate(gen, rng, 4)?),
            None => None,
        };
        let last = history.last().copied().context("zero steps")?;
        Ok(TrainReport {
            final_loss: last.loss,
            final_acc: last.acc,
            steps_per_s: steps as f64 / wall,
            history,
            eval,
        })
    }

    /// Evaluate on `batches` fresh held-out batches; returns (loss, acc).
    pub fn evaluate<G: TaskGenerator>(
        &self,
        gen: &G,
        rng: &mut Pcg64,
        batches: usize,
    ) -> Result<(f32, f32)> {
        let eval_exe = self.eval_exe.as_ref().context("no eval artifact attached")?;
        let eb = eval_exe.batch.context("eval artifact missing batch")?;
        let en = eval_exe.seq_len.context("eval artifact missing seq_len")?;
        let (mut loss_sum, mut acc_sum) = (0.0f32, 0.0f32);
        for _ in 0..batches {
            let batch = generate_batch(gen, rng, eb, en);
            let (loss, acc) = self.evaluate_batch(&batch.tokens, &batch.labels)?;
            loss_sum += loss;
            acc_sum += acc;
        }
        Ok((loss_sum / batches as f32, acc_sum / batches as f32))
    }

    /// Evaluate one explicit batch with current parameters.
    pub fn evaluate_batch(&self, tokens: &[Vec<i32>], labels: &[i32]) -> Result<(f32, f32)> {
        let eval_exe = self.eval_exe.as_ref().context("no eval artifact attached")?;
        let tokens_lit = literal::tokens_to_literal(tokens)?;
        let labels_lit = literal::labels_to_literal(labels);
        // Parameters are borrowed — no copies on the eval path.
        let inputs: Vec<&xla::Literal> = self.state[..self.n_leaves]
            .iter()
            .chain([&tokens_lit, &labels_lit])
            .collect();
        let outputs = eval_exe.run(&inputs)?;
        Ok((
            literal::literal_to_f32(&outputs[0])?,
            literal::literal_to_f32(&outputs[1])?,
        ))
    }

    /// Current parameter tensors (host copies).
    pub fn params(&self) -> Result<Vec<Tensor>> {
        self.state[..self.n_leaves]
            .iter()
            .map(literal::literal_to_tensor)
            .collect()
    }

    /// Parameter leaf names from the manifest.
    pub fn param_names(&self) -> Vec<String> {
        self.exe.io.params.iter().map(|s| s.name.clone()).collect()
    }

    /// Save parameters (not optimizer state) as a checkpoint.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        super::checkpoint::save(path, &self.param_names(), &self.params()?)
    }

    /// Restore parameters from a checkpoint (moments reset to zero).
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let (names, tensors) = super::checkpoint::load(path)?;
        if names != self.param_names() {
            bail!("checkpoint layout mismatch");
        }
        for (i, t) in tensors.iter().enumerate() {
            self.state[i] = literal::tensor_to_literal(t)?;
        }
        Ok(())
    }
}

//! Binary checkpoints: flat little-endian f32 tensors with a JSON
//! sidecar (same wire format as the AOT `*.params.bin` blobs, so
//! checkpoints and initial parameters are interchangeable).

use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Write `tensors` (+ shapes sidecar) to `path` / `path.json`.
pub fn save(path: &Path, names: &[String], tensors: &[Tensor]) -> Result<()> {
    assert_eq!(names.len(), tensors.len());
    let mut bytes = Vec::new();
    for t in tensors {
        for &x in t.data() {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    let meta = Json::Arr(
        names
            .iter()
            .zip(tensors)
            .map(|(n, t)| {
                Json::from_pairs(vec![
                    ("name", Json::Str(n.clone())),
                    (
                        "shape",
                        Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                    ),
                ])
            })
            .collect(),
    );
    std::fs::write(path.with_extension("json"), meta.to_string_pretty())
        .context("writing checkpoint sidecar")?;
    Ok(())
}

/// Load a checkpoint saved by [`save`].
pub fn load(path: &Path) -> Result<(Vec<String>, Vec<Tensor>)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let meta_text = std::fs::read_to_string(path.with_extension("json"))
        .context("reading checkpoint sidecar")?;
    let meta = Json::parse(&meta_text).context("parsing checkpoint sidecar")?;
    let entries = meta
        .as_arr()
        .context("sidecar must be an array")?;
    let mut names = Vec::new();
    let mut tensors = Vec::new();
    let mut offset = 0usize;
    for e in entries {
        let name = e
            .get("name")
            .and_then(|x| x.as_str())
            .context("entry missing name")?
            .to_string();
        let shape = e
            .get("shape")
            .and_then(|x| x.as_usize_vec())
            .context("entry missing shape")?;
        let count: usize = shape.iter().product();
        if (offset + count) * 4 > bytes.len() {
            bail!("checkpoint truncated at {name}");
        }
        let data: Vec<f32> = bytes[offset * 4..(offset + count) * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        tensors.push(Tensor::new(&shape, data));
        names.push(name);
        offset += count;
    }
    if offset * 4 != bytes.len() {
        bail!("checkpoint has {} trailing bytes", bytes.len() - offset * 4);
    }
    Ok((names, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("ts_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let names = vec!["a/w".to_string(), "b/tau".to_string()];
        let tensors = vec![Tensor::randn(&[3, 4], 1), Tensor::randn(&[2], 2)];
        save(&path, &names, &tensors).unwrap();
        let (n2, t2) = load(&path).unwrap();
        assert_eq!(n2, names);
        assert_eq!(t2, tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_fails() {
        let dir = std::env::temp_dir().join(format!("ts_ckpt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        let names = vec!["w".to_string()];
        let tensors = vec![Tensor::randn(&[4, 4], 3)];
        save(&path, &names, &tensors).unwrap();
        // Corrupt: drop last 8 bytes.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Training driver: the rust loop around an AOT train-step executable.
//!
//! The whole optimization step (forward, backward, LAMB update, lr
//! schedule) is one HLO module; this module owns the loop — data
//! generation via `data::*`, state round-tripping, loss/accuracy
//! tracking, periodic evaluation, and binary checkpointing.

pub mod checkpoint;
pub mod driver;

pub use driver::{TrainDriver, TrainReport, TrainStats};

//! TaylorShift CLI — leader entrypoint for the L3 coordinator.
//!
//! Subcommands:
//!   serve     — start the inference engine and run a synthetic client load
//!   train     — run a training loop over an AOT train-step artifact
//!   analyze   — print the paper's analytical tables (Table 2, head scaling)
//!   artifacts — list the artifact registry
//!
//! See README for recipes.

use taylorshift::analysis::{mhsa, transitions};
use taylorshift::bench_support::Table;
use taylorshift::config::ServerConfig;
use taylorshift::coordinator::engine::{Engine, RegistryExecutor};
use taylorshift::data::listops::ListOpsGen;
use taylorshift::data::TaskGenerator;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional().first().map(|s| s.as_str()) {
        Some("analyze") => analyze(&args),
        Some("artifacts") => artifacts(&args),
        Some("train") => train(&args),
        Some("serve") => serve(&args),
        _ => {
            eprintln!(
                "usage: taylorshift <analyze|artifacts|train|serve> [--flags]\n\
                 \n\
                 analyze            print Table 2 transition points + head scaling\n\
                 artifacts          list the AOT artifact registry\n\
                 train              run a training loop (--artifact NAME --steps N)\n\
                 serve              start engine + synthetic load (--requests N --variant auto)"
            );
            Ok(())
        }
    }
}

fn analyze(args: &Args) -> anyhow::Result<()> {
    if args.flag("roofline") {
        return roofline();
    }
    println!("Table 2 — transition points N0 (speed) / N1 (memory):\n");
    let mut t = Table::new(&["d", "N0", "N1", "N0 bound", "N1 bound"]);
    for (d, n0, n1) in transitions::table2() {
        t.row(&[
            d.to_string(),
            n0.to_string(),
            n1.to_string(),
            format!("{:.0}", transitions::n0_bound(d)),
            format!("{:.0}", transitions::n1_bound(d)),
        ]);
    }
    t.print();
    println!(
        "\nFLOP-optimal per-head dim d* = {:.4} (root of 9d^3+10d^2=4, Sec. 4.3)",
        transitions::d_star_ops()
    );
    println!("\nHead scaling at d_emb=256, N=1024 (Section 4.3):\n");
    let mut t = Table::new(&[
        "h",
        "d",
        "ops_eff[MHSA]",
        "ops_triv[MHSA]",
        "entries_eff",
        "entries_triv",
    ]);
    for &h in &[4u64, 8, 16, 32, 64] {
        t.row(&[
            h.to_string(),
            (256 / h).to_string(),
            mhsa::ops_efficient_mhsa(1024, 256, h).to_string(),
            mhsa::ops_direct_mhsa(1024, 256, h).to_string(),
            mhsa::entries_efficient_mhsa(1024, 256, h).to_string(),
            mhsa::entries_direct_mhsa(1024, 256, h).to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// L1 §Perf deliverable: TPU roofline/VMEM estimates for the Pallas
/// BlockSpecs (interpret=True gives no TPU wallclock — these are the
/// structural numbers DESIGN.md §Hardware-Adaptation commits to).
fn roofline() -> anyhow::Result<()> {
    use taylorshift::analysis::roofline::{direct_schedule, efficient_schedule, TpuSpec};
    let spec = TpuSpec::default();
    println!(
        "TPU spec: VMEM {} MiB, peak {:.1} TFLOP/s, HBM {:.0} GB/s, balance {:.0} FLOP/B\n",
        spec.vmem_bytes >> 20,
        spec.peak_flops / 1e12,
        spec.hbm_bw / 1e9,
        spec.peak_flops / spec.hbm_bw
    );
    let mut t = Table::new(&[
        "kernel", "N", "d", "block", "VMEM", "fits", "MXU frac", "intensity", "bound", "est time", "eff",
    ]);
    for (n, d) in [(4096u64, 16u64), (16384, 64), (65536, 64)] {
        for bn in [128u64, 256, 512] {
            let s = efficient_schedule(n, d, bn, 4);
            let e = s.estimate(&spec);
            t.row(&[
                "efficient".into(),
                n.to_string(),
                d.to_string(),
                bn.to_string(),
                format!("{:.1} MiB", e.vmem_bytes as f64 / (1 << 20) as f64),
                if e.fits_vmem { "✓" } else { "✗" }.into(),
                format!("{:.3}", e.mxu_fraction),
                format!("{:.0}", e.arithmetic_intensity),
                if e.compute_bound { "compute" } else { "memory" }.into(),
                taylorshift::bench_support::fmt_seconds(e.runtime_s),
                format!("{:.2}", e.efficiency),
            ]);
        }
        let s = direct_schedule(n, d, 256, 4);
        let e = s.estimate(&spec);
        t.row(&[
            "direct".into(),
            n.to_string(),
            d.to_string(),
            "256".into(),
            format!("{:.1} MiB", e.vmem_bytes as f64 / (1 << 20) as f64),
            if e.fits_vmem { "✓" } else { "✗" }.into(),
            format!("{:.3}", e.mxu_fraction),
            format!("{:.0}", e.arithmetic_intensity),
            if e.compute_bound { "compute" } else { "memory" }.into(),
            taylorshift::bench_support::fmt_seconds(e.runtime_s),
            format!("{:.2}", e.efficiency),
        ]);
    }
    t.print();
    println!(
        "\nreading: 'eff' is modeled fraction-of-peak under the roofline — the paper's\n\
         efficiency-ratio target; block choice trades VMEM fit vs per-step overhead."
    );
    Ok(())
}

fn artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts-dir", "artifacts");
    let reg = Registry::open(Runtime::cpu()?, dir)?;
    let mut t = Table::new(&["artifact", "kind", "batch", "seq_len", "params"]);
    for name in reg.names() {
        let e = reg.entry(&name)?;
        t.row(&[
            name.clone(),
            e.get("kind")
                .and_then(|k| k.as_str())
                .unwrap_or("?")
                .to_string(),
            e.get("batch")
                .and_then(|b| b.as_usize())
                .map(|b| b.to_string())
                .unwrap_or_default(),
            e.get("seq_len")
                .and_then(|b| b.as_usize())
                .map(|b| b.to_string())
                .unwrap_or_default(),
            e.get("num_params")
                .and_then(|b| b.as_usize())
                .map(|b| b.to_string())
                .unwrap_or_default(),
        ]);
    }
    t.print();
    Ok(())
}

fn train(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts-dir", "artifacts");
    let artifact = args.str_or("artifact", "listops_efficient_train_b16");
    let steps = args.usize_or("steps", 200);
    let seed = args.u64_or("seed", 42);
    let reg = Registry::open(Runtime::cpu()?, dir)?;
    let mut driver = TrainDriver::new(&reg, artifact)?;
    // Pick the data generator from the artifact's task prefix
    // (listops_* / pixel_* / textbytes_*; serve_* is listops-backed).
    let task = artifact.split('_').next().unwrap_or("listops");
    let task = if task == "serve" { "listops" } else { task };
    let gen = taylorshift::data::task_by_name(task, driver.seq_len())
        .ok_or_else(|| anyhow::anyhow!("unknown task prefix '{task}' in artifact name"))?;
    let mut rng = Pcg64::new(seed);
    println!(
        "training {artifact} for {steps} steps (B={}, N={})",
        driver.batch_size(),
        driver.seq_len()
    );
    let report = driver.run(&gen, &mut rng, steps, |s| {
        if s.step % 10 == 0 {
            println!(
                "step {:>5}  loss {:.4}  acc {:.3}  ({:.0} ms)",
                s.step,
                s.loss,
                s.acc,
                s.step_time_s * 1e3
            );
        }
    })?;
    println!(
        "done: final loss {:.4}, acc {:.3}, {:.2} steps/s",
        report.final_loss, report.final_acc, report.steps_per_s
    );
    if let Some(out) = args.get("checkpoint") {
        driver.save_checkpoint(std::path::Path::new(out))?;
        println!("checkpoint written to {out}");
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let config = match args.get("config") {
        Some(path) => ServerConfig::from_file(path)?,
        None => ServerConfig::default(),
    };
    let requests = args.usize_or("requests", 64);
    let seed = args.u64_or("seed", 1);
    let mut engine_cfg = config.engine.clone();
    if let Some(v) = args.get("variant") {
        engine_cfg.forced_variant = match v {
            "auto" => None,
            other => taylorshift::attention::AttentionVariant::parse(other),
        };
    }
    if let Some(cal) = args.get("calibration") {
        engine_cfg.selector =
            taylorshift::attention::selector::Selector::from_json_file(std::path::Path::new(cal))?;
        println!(
            "using calibrated crossover from {cal}: N̂0({}) = {:.0}",
            engine_cfg.head_dim,
            engine_cfg.selector.crossover(engine_cfg.head_dim)
        );
    }
    let dir = config.artifacts_dir.clone();
    let prefix = config.prefix.clone();
    let buckets = config.buckets.clone();
    let batch_sizes = config.batch_sizes.clone();
    println!(
        "starting engine (buckets {buckets:?}, adaptive crossover N0({})≈{:.0})",
        engine_cfg.head_dim,
        taylorshift::attention::selector::Selector::analytical().crossover(engine_cfg.head_dim)
    );
    let engine = Engine::start_with(engine_cfg, move || {
        RegistryExecutor::new(&dir, &prefix, &buckets, &batch_sizes)
    })?;

    // Synthetic client load: mixed-length ListOps queries.
    let gen = ListOpsGen {
        min_len: 16,
        max_len: 900,
        ..Default::default()
    };
    let mut rng = Pcg64::new(seed);
    let mut rxs = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..requests {
        let ex = gen.generate(&mut rng);
        match engine.submit(ex.tokens) {
            Ok(rx) => rxs.push(rx),
            Err(e) => println!("rejected: {e}"),
        }
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{requests} requests in {wall:.2}s ({:.1} req/s)\n",
        ok as f64 / wall
    );
    println!("{}", engine.metrics().summary());
    Ok(())
}

//! Request/response types for the serving path.

use std::time::Instant;

/// Typed handle to an open decode stream: the session id plus the
/// observability trace id minted at open. Returned by
/// `Engine::submit_stream` and accepted (via [`AsSessionId`]) by
/// `decode_step`/`close_stream`, so trace correlation needs no
/// separate lookup. Dropping it unused is almost certainly a leaked
/// stream — hence `#[must_use]`.
#[must_use = "a SessionHandle is the only reference to an open stream; close it or step it"]
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionHandle {
    id: u64,
    trace: u64,
}

impl SessionHandle {
    /// Constructed by the engine when a stream opens.
    pub(crate) fn new(id: u64, trace: u64) -> Self {
        Self { id, trace }
    }

    /// The stream's session id (what the store keys on).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The stream's observability trace id — matches every span and
    /// flight-recorder event the stream produces.
    pub fn trace(&self) -> u64 {
        self.trace
    }
}

impl std::fmt::Display for SessionHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session {} (trace {})", self.id, self.trace)
    }
}

/// Anything that names a decode session. Engine decode/close APIs take
/// `impl AsSessionId`, so callers pass the typed [`SessionHandle`];
/// the `u64` impl is a one-release compatibility shim for older
/// callers that stored raw ids (examples/tests) — prefer the handle.
pub trait AsSessionId {
    fn session_id(&self) -> u64;
}

impl AsSessionId for SessionHandle {
    fn session_id(&self) -> u64 {
        self.id
    }
}

impl AsSessionId for &SessionHandle {
    fn session_id(&self) -> u64 {
        self.id
    }
}

impl AsSessionId for u64 {
    fn session_id(&self) -> u64 {
        *self
    }
}

/// A classification request: one token sequence.
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub enqueued_at: Instant,
    /// Observability trace ID; spans and flight-recorder events for
    /// this request all carry it (see `obs`).
    pub trace: u64,
}

impl InferRequest {
    pub fn new(id: u64, tokens: Vec<i32>) -> Self {
        Self {
            id,
            tokens,
            enqueued_at: Instant::now(),
            trace: crate::obs::next_trace_id(),
        }
    }
}

/// The engine's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    /// Class logits.
    pub logits: Vec<f32>,
    /// Which attention variant served this request.
    pub variant: crate::attention::AttentionVariant,
    /// Bucket (padded sequence length) used.
    pub bucket: usize,
    /// How many requests shared the executed batch.
    pub batch_size: usize,
    /// Total latency: submit → response.
    pub latency: std::time::Duration,
}

impl InferResponse {
    pub fn predicted_class(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// One autoregressive decode step for a streaming session: the next
/// token's embedding row, `[1, d_model]`. The engine's model projects
/// it to per-head q/k/v inside every layer.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub session: u64,
    pub token: crate::tensor::Tensor,
    pub enqueued_at: Instant,
}

impl DecodeRequest {
    pub fn new(session: u64, token: crate::tensor::Tensor) -> Self {
        Self {
            session,
            token,
            enqueued_at: Instant::now(),
        }
    }
}

/// The engine's answer to one decode step.
#[derive(Clone, Debug)]
pub struct DecodeResponse {
    pub session: u64,
    /// Prefix length after this token.
    pub step: usize,
    /// Final-block output row, length `d_model`.
    pub output: Vec<f32>,
    /// Per-layer branch/promotion records for this step.
    pub layers: Vec<crate::model::LayerStep>,
    /// True iff any layer crossed N₀ and promoted KV→recurrent on
    /// this step.
    pub promoted: bool,
    /// Total latency: submit → response.
    pub latency: std::time::Duration,
    /// The stream's trace ID (constant across the session's steps).
    pub trace: u64,
}

/// Closing summary for a finished stream.
#[derive(Clone, Debug)]
pub struct StreamStats {
    pub session: u64,
    /// Tokens decoded over the stream's lifetime.
    pub tokens: usize,
    /// Branch serving each layer at close time.
    pub branches: Vec<crate::attention::AttentionVariant>,
    /// Resident state bytes at close time, all layers summed.
    pub bytes: u64,
    /// Per-layer prefix lengths at which layers promoted (`None` =
    /// layer stayed on the KV branch).
    pub promoted_at: Vec<Option<usize>>,
    /// The stream's trace ID, for correlating with span records.
    pub trace: u64,
    /// True iff the stream was closed while evicted or spilled; the
    /// stats then report what was known at eviction time.
    pub evicted: bool,
}

/// Why a request was rejected or failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// Longer than the largest configured bucket.
    TooLong { len: usize, max: usize },
    /// Queue full (backpressure).
    Overloaded { queued: usize, limit: usize },
    /// Empty token sequence.
    Empty,
    /// Engine shut down before the request completed.
    Shutdown,
    /// PJRT execution failed.
    ExecFailed(String),
    /// Decode step for a session that was never opened or was closed
    /// normally.
    UnknownSession { id: u64 },
    /// Decode step for a session LRU-evicted under memory pressure —
    /// its state is gone and the caller must re-prefill before
    /// streaming again.
    NeedsReprefill { id: u64 },
    /// Decode inputs had the wrong shape for the configured model.
    BadDecodeShape { expected: [usize; 2], got: Vec<usize> },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::TooLong { len, max } => write!(f, "sequence too long: {len} > max bucket {max}"),
            Self::Overloaded { queued, limit } => {
                write!(f, "engine overloaded: {queued} queued (limit {limit})")
            }
            Self::Empty => write!(f, "empty token sequence"),
            Self::Shutdown => write!(f, "engine shut down"),
            Self::ExecFailed(e) => write!(f, "execution failed: {e}"),
            Self::UnknownSession { id } => {
                write!(f, "unknown decode session {id} (never opened or closed)")
            }
            Self::NeedsReprefill { id } => {
                write!(
                    f,
                    "decode session {id} was evicted under memory pressure; re-prefill required"
                )
            }
            Self::BadDecodeShape { expected, got } => {
                write!(f, "decode input shape {got:?}, expected {expected:?}")
            }
        }
    }
}

impl std::error::Error for RequestError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicted_class_is_argmax() {
        let r = InferResponse {
            id: 1,
            logits: vec![0.1, 2.0, -1.0],
            variant: crate::attention::AttentionVariant::Direct,
            bucket: 128,
            batch_size: 1,
            latency: std::time::Duration::from_millis(1),
        };
        assert_eq!(r.predicted_class(), 1);
    }

    #[test]
    fn session_handle_carries_id_and_trace() {
        let h = SessionHandle::new(7, 99);
        assert_eq!(h.id(), 7);
        assert_eq!(h.trace(), 99);
        assert_eq!(h.session_id(), 7);
        assert_eq!((&h).session_id(), 7);
        assert_eq!(7u64.session_id(), 7, "u64 shim still names a session");
        assert!(h.to_string().contains("trace 99"));
    }

    #[test]
    fn errors_display() {
        let e = RequestError::TooLong { len: 5000, max: 1024 };
        assert!(e.to_string().contains("5000"));
        let e = RequestError::Overloaded { queued: 100, limit: 64 };
        assert!(e.to_string().contains("overloaded"));
        let e = RequestError::UnknownSession { id: 42 };
        assert!(e.to_string().contains("42"));
        let e = RequestError::NeedsReprefill { id: 7 };
        assert!(e.to_string().contains("re-prefill"));
        let e = RequestError::BadDecodeShape {
            expected: [4, 16],
            got: vec![2, 16],
        };
        assert!(e.to_string().contains("[4, 16]"));
    }
}

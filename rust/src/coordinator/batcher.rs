//! Dynamic batcher: per-bucket pending queues with a max-size /
//! max-delay flush policy (the standard continuous-batching tradeoff:
//! larger batches amortize execution, the delay cap bounds added
//! latency).
//!
//! Pure data structure — no threads, no clocks of its own. The engine
//! thread drives it with explicit `now` instants, which makes the flush
//! policy deterministic and directly testable (including by property
//! tests: conservation, FIFO order, deadline respect).

use crate::coordinator::request::InferRequest;
use crate::coordinator::router::Route;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A flushed group ready for execution.
#[derive(Debug)]
pub struct PendingBatch {
    pub route: Route,
    pub requests: Vec<(InferRequest, ResponderId)>,
}

/// Opaque ticket the engine uses to pair responses with waiters.
pub type ResponderId = u64;

struct Queue {
    route: Route,
    items: Vec<(InferRequest, ResponderId)>,
    oldest: Option<Instant>,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are pending in a bucket.
    pub max_batch: usize,
    /// Flush any queue whose oldest request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_delay: Duration::from_millis(5),
        }
    }
}

/// The batcher: queues keyed by (bucket, variant).
pub struct DynamicBatcher {
    policy: BatchPolicy,
    queues: BTreeMap<(usize, u8), Queue>,
    pending_total: usize,
}

fn variant_key(v: crate::attention::AttentionVariant) -> u8 {
    match v {
        crate::attention::AttentionVariant::Direct => 0,
        crate::attention::AttentionVariant::Efficient => 1,
        crate::attention::AttentionVariant::Softmax => 2,
    }
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            queues: BTreeMap::new(),
            pending_total: 0,
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Total requests currently queued (for backpressure checks).
    pub fn pending(&self) -> usize {
        self.pending_total
    }

    /// Enqueue a routed request. Returns batches that became ready
    /// because of this arrival (max_batch reached).
    pub fn push(
        &mut self,
        route: Route,
        request: InferRequest,
        responder: ResponderId,
        now: Instant,
    ) -> Vec<PendingBatch> {
        let key = (route.bucket, variant_key(route.variant));
        let queue = self.queues.entry(key).or_insert_with(|| Queue {
            route,
            items: Vec::new(),
            oldest: None,
        });
        if queue.items.is_empty() {
            queue.oldest = Some(now);
        }
        crate::obs::recorder::record_event(
            crate::obs::recorder::EventKind::Enqueue,
            request.trace,
            route.bucket as u64,
            queue.items.len() as u64 + 1,
        );
        queue.items.push((request, responder));
        self.pending_total += 1;
        if queue.items.len() >= self.policy.max_batch {
            let batch = Self::drain_queue(queue, self.policy.max_batch);
            self.pending_total -= batch.requests.len();
            vec![batch]
        } else {
            Vec::new()
        }
    }

    /// Flush every queue whose oldest entry has exceeded max_delay.
    pub fn flush_due(&mut self, now: Instant) -> Vec<PendingBatch> {
        let mut out = Vec::new();
        for queue in self.queues.values_mut() {
            while !queue.items.is_empty()
                && queue
                    .oldest
                    .map(|t| now.duration_since(t) >= self.policy.max_delay)
                    .unwrap_or(false)
            {
                let batch = Self::drain_queue(queue, self.policy.max_batch);
                self.pending_total -= batch.requests.len();
                out.push(batch);
            }
        }
        out
    }

    /// Flush everything regardless of age (shutdown path).
    pub fn flush_all(&mut self) -> Vec<PendingBatch> {
        let mut out = Vec::new();
        for queue in self.queues.values_mut() {
            while !queue.items.is_empty() {
                let batch = Self::drain_queue(queue, self.policy.max_batch);
                self.pending_total -= batch.requests.len();
                out.push(batch);
            }
        }
        out
    }

    /// Next instant at which a queue becomes due, if any (engine uses
    /// this for its recv timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter(|q| !q.items.is_empty())
            .filter_map(|q| q.oldest)
            .map(|t| t + self.policy.max_delay)
            .min()
    }

    fn drain_queue(queue: &mut Queue, max: usize) -> PendingBatch {
        let take = queue.items.len().min(max);
        crate::obs::recorder::record_event(
            crate::obs::recorder::EventKind::BatchSeal,
            0,
            take as u64,
            queue.route.bucket as u64,
        );
        let requests: Vec<_> = queue.items.drain(..take).collect();
        queue.oldest = if queue.items.is_empty() {
            None
        } else {
            // Remaining entries inherited the arrival order; their oldest
            // is the first remaining request's enqueue time.
            Some(queue.items[0].0.enqueued_at)
        };
        PendingBatch {
            route: queue.route,
            requests,
        }
    }
}

/// Priority lane for streaming decode steps, drained by the engine
/// ahead of due prefill batches each drive cycle.
///
/// Decode steps are O(1)-ish units on the hot serving path: making a
/// token wait behind a whole prefill batch wrecks per-token latency,
/// but letting an unbounded decode burst starve prefill wrecks
/// throughput. The lane resolves the mix: FIFO within decode, at most
/// `max_per_cycle` steps run before the engine services due batches,
/// and anything left keeps the engine's poll timeout at zero so the
/// remainder runs on the immediately following cycle.
pub struct DecodeLane<T> {
    items: std::collections::VecDeque<T>,
    max_per_cycle: usize,
}

impl<T> DecodeLane<T> {
    pub fn new(max_per_cycle: usize) -> Self {
        Self {
            items: std::collections::VecDeque::new(),
            max_per_cycle: max_per_cycle.max(1),
        }
    }

    pub fn push(&mut self, item: T) {
        self.items.push_back(item);
    }

    pub fn pending(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Up to `max_per_cycle` steps, FIFO.
    pub fn drain_cycle(&mut self) -> Vec<T> {
        let take = self.items.len().min(self.max_per_cycle);
        self.items.drain(..take).collect()
    }

    /// Everything, FIFO (shutdown path).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.items.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionVariant;
    use crate::testing::prop::{run, Config, Gen};

    fn route(bucket: usize) -> Route {
        Route {
            bucket,
            variant: if bucket > 256 {
                AttentionVariant::Efficient
            } else {
                AttentionVariant::Direct
            },
        }
    }

    fn req(id: u64) -> InferRequest {
        InferRequest::new(id, vec![1, 2, 3])
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 3,
            max_delay: Duration::from_secs(60),
        });
        let now = Instant::now();
        assert!(b.push(route(128), req(1), 1, now).is_empty());
        assert!(b.push(route(128), req(2), 2, now).is_empty());
        let ready = b.push(route(128), req(3), 3, now);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].requests.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(10),
        });
        let t0 = Instant::now();
        b.push(route(512), req(1), 1, t0);
        assert!(b.flush_due(t0 + Duration::from_millis(5)).is_empty());
        let ready = b.flush_due(t0 + Duration::from_millis(11));
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].route.bucket, 512);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn buckets_do_not_mix() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_delay: Duration::from_secs(60),
        });
        let now = Instant::now();
        b.push(route(128), req(1), 1, now);
        let ready = b.push(route(512), req(2), 2, now);
        assert!(ready.is_empty(), "different buckets must not co-flush");
        let ready = b.push(route(128), req(3), 3, now);
        assert_eq!(ready.len(), 1);
        assert!(ready[0].requests.iter().all(|(r, _)| r.id != 2));
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(10),
        });
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.push(route(128), req(1), 1, t0);
        let dl = b.next_deadline().unwrap();
        assert_eq!(dl, t0 + Duration::from_millis(10));
    }

    #[test]
    fn flush_all_empties() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        let now = Instant::now();
        for i in 0..5 {
            b.push(route(if i % 2 == 0 { 128 } else { 512 }), req(i), i, now);
        }
        let batches = b.flush_all();
        let total: usize = batches.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 5);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn prop_conservation_and_fifo() {
        // Every pushed request comes out exactly once, and within a
        // bucket, in FIFO order.
        run(
            Config::default().cases(128),
            Gen::vec(Gen::usize_range(0, 3), 1, 64),
            |bucket_choices| {
                let buckets = [128usize, 256, 512, 1024];
                let mut b = DynamicBatcher::new(BatchPolicy {
                    max_batch: 4,
                    max_delay: Duration::from_secs(60),
                });
                let now = Instant::now();
                let mut flushed: Vec<PendingBatch> = Vec::new();
                for (i, &choice) in bucket_choices.iter().enumerate() {
                    flushed.extend(b.push(
                        route(buckets[choice]),
                        req(i as u64),
                        i as u64,
                        now,
                    ));
                }
                flushed.extend(b.flush_all());
                // conservation
                let mut ids: Vec<u64> = flushed
                    .iter()
                    .flat_map(|batch| batch.requests.iter().map(|(r, _)| r.id))
                    .collect();
                ids.sort_unstable();
                if ids != (0..bucket_choices.len() as u64).collect::<Vec<_>>() {
                    return false;
                }
                // FIFO per bucket
                let mut last_seen: std::collections::HashMap<usize, u64> = Default::default();
                for batch in &flushed {
                    for (r, _) in &batch.requests {
                        if let Some(&prev) = last_seen.get(&batch.route.bucket) {
                            if r.id <= prev {
                                return false;
                            }
                        }
                        last_seen.insert(batch.route.bucket, r.id);
                    }
                }
                // batch size cap
                flushed.iter().all(|b| b.requests.len() <= 4)
            },
        );
    }

    #[test]
    fn decode_lane_bounds_each_cycle_and_keeps_fifo() {
        let mut lane = DecodeLane::new(3);
        for i in 0..8u64 {
            lane.push(i);
        }
        assert_eq!(lane.pending(), 8);
        assert_eq!(lane.drain_cycle(), vec![0, 1, 2]);
        assert_eq!(lane.drain_cycle(), vec![3, 4, 5]);
        assert_eq!(lane.pending(), 2);
        assert_eq!(lane.drain_all(), vec![6, 7]);
        assert!(lane.is_empty());
        assert!(lane.drain_cycle().is_empty());
    }

    #[test]
    fn decode_lane_cycle_cap_is_at_least_one() {
        let mut lane = DecodeLane::new(0);
        lane.push(1u64);
        assert_eq!(lane.drain_cycle(), vec![1]);
    }

    #[test]
    fn prop_pending_counter_consistent() {
        run(
            Config::default().cases(64),
            Gen::vec(Gen::usize_range(0, 1), 0, 40),
            |choices| {
                let mut b = DynamicBatcher::new(BatchPolicy {
                    max_batch: 3,
                    max_delay: Duration::from_secs(60),
                });
                let now = Instant::now();
                let mut out = 0usize;
                for (i, &c) in choices.iter().enumerate() {
                    let batches =
                        b.push(route(if c == 0 { 128 } else { 512 }), req(i as u64), 0, now);
                    out += batches.iter().map(|x| x.requests.len()).sum::<usize>();
                }
                b.pending() + out == choices.len()
            },
        );
    }
}

//! Serving metrics: counters and log-scale latency histograms,
//! lock-free on the hot path (atomics only).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log₂-bucketed latency histogram from 1 µs to ~1 hour.
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i µs, 2^(i+1) µs).
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

const HIST_BUCKETS: usize = 32;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Approximate quantile from the log₂ buckets, linearly
    /// interpolated by rank within the winning bucket. (Returning the
    /// bucket's upper bound, as this used to, overestimates p50/p99
    /// by up to 2× whenever the quantile rank falls early in a
    /// well-populated bucket.)
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = (((n as f64) * q.clamp(0.0, 1.0)).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let in_bucket = b.load(Ordering::Relaxed);
            seen += in_bucket;
            if seen >= target && in_bucket > 0 {
                let lo = 1u64 << i;
                let hi = 1u64 << (i + 1);
                let rank_in_bucket = (target - (seen - in_bucket)) as f64;
                let fraction = rank_in_bucket / in_bucket as f64;
                let us = lo as f64 + fraction * (hi - lo) as f64;
                return Duration::from_micros(us as u64);
            }
        }
        Duration::from_micros(1u64 << HIST_BUCKETS)
    }

    /// Copy-out snapshot in the shared log₂ format consumed by the
    /// Prometheus renderer (`obs::prometheus`).
    pub fn snapshot(&self) -> crate::obs::collector::HistSnapshot {
        let mut snap = crate::obs::collector::HistSnapshot::default();
        for (out, b) in snap.buckets.iter_mut().zip(self.buckets.iter()) {
            *out = b.load(Ordering::Relaxed);
        }
        snap.sum_us = self.sum_us.load(Ordering::Relaxed);
        snap.count = self.count.load(Ordering::Relaxed);
        snap
    }
}

/// What a [`Sample`] is — drives the `# TYPE` header the Prometheus
/// renderer (`obs::prometheus`) emits for it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampleKind {
    Counter,
    Gauge,
    /// Derived scalar of a histogram ([`Sample::stat`] says which);
    /// the renderer skips these in favour of native bucket series.
    Histogram,
}

/// One exported scalar sample from [`Metrics::export`].
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Registered metric name: snake_case with a unit suffix
    /// (`_bytes`, `_us`, `_total`) — enforced by taylor-lint rule R5.
    pub name: &'static str,
    /// Derived statistic for histograms (`"p50"`, `"p99"`, `"mean"`,
    /// `"count"`); empty for plain counters and gauges.
    pub stat: &'static str,
    /// Per-layer gauge index (a label, not part of the name).
    pub layer: Option<usize>,
    pub value: f64,
    pub kind: SampleKind,
}

/// Register a monotonic counter sample.
fn register_counter(out: &mut Vec<Sample>, name: &'static str, v: &AtomicU64) {
    out.push(Sample {
        name,
        stat: "",
        layer: None,
        value: v.load(Ordering::Relaxed) as f64,
        kind: SampleKind::Counter,
    });
}

/// Register a gauge sample, optionally labelled with a layer index.
fn register_gauge(out: &mut Vec<Sample>, name: &'static str, layer: Option<usize>, value: u64) {
    out.push(Sample {
        name,
        stat: "",
        layer,
        value: value as f64,
        kind: SampleKind::Gauge,
    });
}

/// Register a float-valued gauge sample (ratios like occupancy).
fn register_gauge_f(out: &mut Vec<Sample>, name: &'static str, value: f64) {
    out.push(Sample {
        name,
        stat: "",
        layer: None,
        value,
        kind: SampleKind::Gauge,
    });
}

/// Register the derived samples of a latency histogram. The registered
/// base name carries the `_us` unit; the statistic rides in
/// [`Sample::stat`] (count is a raw sample count, not µs).
fn register_histogram(out: &mut Vec<Sample>, name: &'static str, h: &LatencyHistogram) {
    for (stat, value) in [
        ("count", h.count() as f64),
        ("mean", h.mean().as_micros() as f64),
        ("p50", h.quantile(0.5).as_micros() as f64),
        ("p99", h.quantile(0.99).as_micros() as f64),
    ] {
        out.push(Sample {
            name,
            stat,
            layer: None,
            value,
            kind: SampleKind::Histogram,
        });
    }
}

/// All engine metrics.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches_executed: AtomicU64,
    /// Sum of real requests across executed batches (for mean occupancy).
    pub batched_requests: AtomicU64,
    /// Padding rows executed (batch-slot waste).
    pub padding_rows: AtomicU64,
    /// End-to-end latency.
    pub latency: LatencyHistogram,
    /// Time spent waiting in the batcher.
    pub queue_wait: LatencyHistogram,
    /// Pure executable runtime.
    pub exec_time: LatencyHistogram,
    /// Per-variant request counts [direct, efficient, softmax].
    pub variant_counts: [AtomicU64; 3],
    // --- streaming decode (see `decode/`) ---
    /// Streams opened via `submit_stream`.
    pub streams_opened: AtomicU64,
    /// Streams closed via `close_stream`.
    pub streams_closed: AtomicU64,
    /// Decode steps served from resident session state (cache hits).
    pub decode_steps: AtomicU64,
    /// Decode steps that missed (session unknown/closed/evicted).
    pub decode_misses: AtomicU64,
    /// KV→recurrent promotions at the crossover.
    pub promotions: AtomicU64,
    /// Sessions LRU-evicted under the memory budget.
    pub sessions_evicted: AtomicU64,
    /// Evictions whose state survived to a spill file (subset of
    /// `sessions_evicted`).
    pub sessions_spilled: AtomicU64,
    /// Spilled sessions transparently restored on touch.
    pub sessions_restored: AtomicU64,
    /// Restores that failed spill-file validation (checksum/version/
    /// shape) and degraded to a hard eviction.
    pub spill_failures: AtomicU64,
    /// Gauge: sessions currently resident in the store.
    pub sessions_resident: AtomicU64,
    /// Gauge: bytes held by resident session state (all layers summed).
    pub session_bytes: AtomicU64,
    /// Gauge: sessions currently parked in spill files.
    pub sessions_spilled_resident: AtomicU64,
    /// Gauge: on-disk bytes held by spill files.
    pub spill_file_bytes: AtomicU64,
    /// Cumulative resident bytes rehydrated by restores.
    pub restored_state_bytes: AtomicU64,
    /// Per-token decode latency (submit → response).
    pub decode_latency: LatencyHistogram,
    /// Whole-model per-token step time (store.step only, excluding
    /// queueing).
    pub model_step_time: LatencyHistogram,
    /// Spill-file restore latency (read + validate + decode).
    pub restore_latency: LatencyHistogram,
    /// Gauge per layer: resident sessions served on the KV branch.
    pub layer_kv_sessions: Vec<AtomicU64>,
    /// Gauge per layer: resident sessions served recurrent.
    pub layer_recurrent_sessions: Vec<AtomicU64>,
    /// Gauge: decode requests waiting in the priority lane
    /// (maintained by the engine loop on enqueue/drain).
    pub decode_lane_depth: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Metrics with per-layer branch-occupancy gauges sized for an
    /// `n_layers`-deep streaming model.
    pub fn with_layers(n_layers: usize) -> Self {
        Self {
            layer_kv_sessions: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
            layer_recurrent_sessions: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
            ..Self::default()
        }
    }

    pub fn record_variant(&self, v: crate::attention::AttentionVariant) {
        let idx = match v {
            crate::attention::AttentionVariant::Direct => 0,
            crate::attention::AttentionVariant::Efficient => 1,
            crate::attention::AttentionVariant::Softmax => 2,
        };
        self.variant_counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Mean requests per executed batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let batches = self.batches_executed.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
    }

    /// Snapshot of a gauge vector, e.g. `[3, 0, 1]`.
    fn gauge_vec(gauges: &[AtomicU64]) -> Vec<u64> {
        gauges.iter().map(|g| g.load(Ordering::Relaxed)).collect()
    }

    /// Flat, name-addressed export of every metric — the registration
    /// surface a scraper consumes. Names follow the machine-checked
    /// convention (snake_case, unit-suffixed); `summary()`/`to_json()`
    /// keep their legacy shapes for humans and the bench gate.
    pub fn export(&self) -> Vec<Sample> {
        let mut out = Vec::new();
        register_counter(&mut out, "requests_submitted_total", &self.submitted);
        register_counter(&mut out, "requests_completed_total", &self.completed);
        register_counter(&mut out, "requests_rejected_total", &self.rejected);
        register_counter(&mut out, "batches_executed_total", &self.batches_executed);
        register_counter(&mut out, "batched_requests_total", &self.batched_requests);
        register_counter(&mut out, "padding_rows_total", &self.padding_rows);
        register_counter(
            &mut out,
            "variant_direct_requests_total",
            &self.variant_counts[0],
        );
        register_counter(
            &mut out,
            "variant_efficient_requests_total",
            &self.variant_counts[1],
        );
        register_counter(
            &mut out,
            "variant_softmax_requests_total",
            &self.variant_counts[2],
        );
        register_counter(&mut out, "streams_opened_total", &self.streams_opened);
        register_counter(&mut out, "streams_closed_total", &self.streams_closed);
        register_counter(&mut out, "decode_steps_total", &self.decode_steps);
        register_counter(&mut out, "decode_misses_total", &self.decode_misses);
        register_counter(&mut out, "promotions_total", &self.promotions);
        register_counter(&mut out, "sessions_evicted_total", &self.sessions_evicted);
        register_counter(&mut out, "sessions_spilled_total", &self.sessions_spilled);
        register_counter(&mut out, "sessions_restored_total", &self.sessions_restored);
        register_counter(&mut out, "spill_failures_total", &self.spill_failures);
        register_gauge_f(&mut out, "batch_occupancy_total", self.mean_batch_occupancy());
        register_gauge(
            &mut out,
            "decode_lane_depth_total",
            None,
            self.decode_lane_depth.load(Ordering::Relaxed),
        );
        register_gauge(
            &mut out,
            "resident_sessions_total",
            None,
            self.sessions_resident.load(Ordering::Relaxed),
        );
        register_gauge(
            &mut out,
            "session_state_bytes",
            None,
            self.session_bytes.load(Ordering::Relaxed),
        );
        register_gauge(
            &mut out,
            "spilled_sessions_total",
            None,
            self.sessions_spilled_resident.load(Ordering::Relaxed),
        );
        register_gauge(
            &mut out,
            "spill_file_bytes",
            None,
            self.spill_file_bytes.load(Ordering::Relaxed),
        );
        register_gauge(
            &mut out,
            "restored_state_bytes",
            None,
            self.restored_state_bytes.load(Ordering::Relaxed),
        );
        for (l, g) in self.layer_kv_sessions.iter().enumerate() {
            register_gauge(
                &mut out,
                "layer_kv_sessions_total",
                Some(l),
                g.load(Ordering::Relaxed),
            );
        }
        for (l, g) in self.layer_recurrent_sessions.iter().enumerate() {
            register_gauge(
                &mut out,
                "layer_recurrent_sessions_total",
                Some(l),
                g.load(Ordering::Relaxed),
            );
        }
        register_histogram(&mut out, "request_latency_us", &self.latency);
        register_histogram(&mut out, "queue_wait_us", &self.queue_wait);
        register_histogram(&mut out, "exec_time_us", &self.exec_time);
        register_histogram(&mut out, "decode_latency_us", &self.decode_latency);
        register_histogram(&mut out, "model_step_time_us", &self.model_step_time);
        register_histogram(&mut out, "restore_latency_us", &self.restore_latency);
        out
    }

    /// The latency histograms behind the `export()` scalar stats,
    /// under their registered base names — the native-histogram
    /// surface the Prometheus renderer consumes. Kept consistent with
    /// `export()` by a unit test.
    pub fn histogram_list(&self) -> [(&'static str, &LatencyHistogram); 6] {
        [
            ("request_latency_us", &self.latency),
            ("queue_wait_us", &self.queue_wait),
            ("exec_time_us", &self.exec_time),
            ("decode_latency_us", &self.decode_latency),
            ("model_step_time_us", &self.model_step_time),
            ("restore_latency_us", &self.restore_latency),
        ]
    }

    /// Human-readable summary block: one report covering the batch
    /// path, the per-variant split, and the streaming-decode state.
    pub fn summary(&self) -> String {
        format!(
            "requests: submitted={} completed={} rejected={}\n\
             batches: executed={} mean_occupancy={:.2} padding_rows={}\n\
             variants: direct={} efficient={} softmax={}\n\
             decode: steps={} misses={} promotions={}\n\
             sessions: opened={} closed={} evicted={} resident={} bytes={}\n\
             spill: spilled={} restored={} failures={} on_disk={} disk_bytes={}\n\
             layers: kv={:?} recurrent={:?}\n\
             latency: mean={:?} p50={:?} p99={:?}\n\
             queue_wait: mean={:?} p99={:?}\n\
             exec: mean={:?} p99={:?}\n\
             decode_latency: mean={:?} p50={:?} p99={:?}\n\
             model_step: mean={:?} p50={:?} p99={:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches_executed.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.padding_rows.load(Ordering::Relaxed),
            self.variant_counts[0].load(Ordering::Relaxed),
            self.variant_counts[1].load(Ordering::Relaxed),
            self.variant_counts[2].load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.decode_misses.load(Ordering::Relaxed),
            self.promotions.load(Ordering::Relaxed),
            self.streams_opened.load(Ordering::Relaxed),
            self.streams_closed.load(Ordering::Relaxed),
            self.sessions_evicted.load(Ordering::Relaxed),
            self.sessions_resident.load(Ordering::Relaxed),
            self.session_bytes.load(Ordering::Relaxed),
            self.sessions_spilled.load(Ordering::Relaxed),
            self.sessions_restored.load(Ordering::Relaxed),
            self.spill_failures.load(Ordering::Relaxed),
            self.sessions_spilled_resident.load(Ordering::Relaxed),
            self.spill_file_bytes.load(Ordering::Relaxed),
            Self::gauge_vec(&self.layer_kv_sessions),
            Self::gauge_vec(&self.layer_recurrent_sessions),
            self.latency.mean(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.queue_wait.mean(),
            self.queue_wait.quantile(0.99),
            self.exec_time.mean(),
            self.exec_time.quantile(0.99),
            self.decode_latency.mean(),
            self.decode_latency.quantile(0.5),
            self.decode_latency.quantile(0.99),
            self.model_step_time.mean(),
            self.model_step_time.quantile(0.5),
            self.model_step_time.quantile(0.99),
        )
    }

    /// Machine-readable snapshot for benches and the server.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let n = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        let hist = |h: &LatencyHistogram| {
            Json::from_pairs(vec![
                ("count", Json::Num(h.count() as f64)),
                ("mean_us", Json::Num(h.mean().as_micros() as f64)),
                ("p50_us", Json::Num(h.quantile(0.5).as_micros() as f64)),
                ("p99_us", Json::Num(h.quantile(0.99).as_micros() as f64)),
            ])
        };
        Json::from_pairs(vec![
            (
                "requests",
                Json::from_pairs(vec![
                    ("submitted", n(&self.submitted)),
                    ("completed", n(&self.completed)),
                    ("rejected", n(&self.rejected)),
                ]),
            ),
            (
                "batches",
                Json::from_pairs(vec![
                    ("executed", n(&self.batches_executed)),
                    ("mean_occupancy", Json::Num(self.mean_batch_occupancy())),
                    ("padding_rows", n(&self.padding_rows)),
                ]),
            ),
            (
                "variants",
                Json::from_pairs(vec![
                    ("direct", n(&self.variant_counts[0])),
                    ("efficient", n(&self.variant_counts[1])),
                    ("softmax", n(&self.variant_counts[2])),
                ]),
            ),
            (
                "decode",
                Json::from_pairs(vec![
                    ("steps", n(&self.decode_steps)),
                    ("misses", n(&self.decode_misses)),
                    ("promotions", n(&self.promotions)),
                ]),
            ),
            (
                "sessions",
                Json::from_pairs(vec![
                    ("opened", n(&self.streams_opened)),
                    ("closed", n(&self.streams_closed)),
                    ("evicted", n(&self.sessions_evicted)),
                    ("resident", n(&self.sessions_resident)),
                    ("bytes", n(&self.session_bytes)),
                ]),
            ),
            (
                "spill",
                Json::from_pairs(vec![
                    ("spilled", n(&self.sessions_spilled)),
                    ("restored", n(&self.sessions_restored)),
                    ("failures", n(&self.spill_failures)),
                    ("on_disk", n(&self.sessions_spilled_resident)),
                    ("disk_bytes", n(&self.spill_file_bytes)),
                    ("restored_bytes", n(&self.restored_state_bytes)),
                ]),
            ),
            (
                "layers",
                Json::Arr(
                    self.layer_kv_sessions
                        .iter()
                        .zip(&self.layer_recurrent_sessions)
                        .map(|(kv, rec)| {
                            Json::from_pairs(vec![
                                ("kv", Json::Num(kv.load(Ordering::Relaxed) as f64)),
                                ("recurrent", Json::Num(rec.load(Ordering::Relaxed) as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("latency", hist(&self.latency)),
            ("queue_wait", hist(&self.queue_wait)),
            ("exec", hist(&self.exec_time)),
            ("decode_latency", hist(&self.decode_latency)),
            ("model_step", hist(&self.model_step_time)),
            ("restore_latency", hist(&self.restore_latency)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.quantile(0.5) <= Duration::from_millis(16));
        assert!(h.quantile(1.0) >= Duration::from_millis(100));
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.5), Duration::ZERO);
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // 100 identical samples land in bucket [512 µs, 1024 µs); the
        // p50 rank is halfway through it, so interpolation must give
        // ~768 µs — strictly inside the bucket, not its upper bound.
        let h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(Duration::from_micros(700));
        }
        let p50 = h.quantile(0.5);
        assert!(p50 >= Duration::from_micros(512), "{p50:?}");
        assert!(p50 < Duration::from_micros(1024), "{p50:?}");
        assert!(
            (p50.as_micros() as i64 - 768).abs() <= 8,
            "p50 should interpolate to ~768 µs, got {p50:?}"
        );
        // The max quantile still reaches the bucket's upper edge.
        assert_eq!(h.quantile(1.0), Duration::from_micros(1024));
    }

    #[test]
    fn snapshot_matches_counts() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(3));
        h.record(Duration::from_micros(700));
        let snap = h.snapshot();
        assert_eq!(snap.count, 2);
        assert_eq!(snap.sum_us, 703);
        assert_eq!(snap.buckets.iter().sum::<u64>(), 2);
        assert_eq!(snap.buckets[1], 1); // [2, 4) µs
        assert_eq!(snap.buckets[9], 1); // [512, 1024) µs
    }

    #[test]
    fn histogram_list_names_match_export() {
        let m = Metrics::new();
        let samples = m.export();
        for (name, _) in m.histogram_list() {
            assert!(
                samples
                    .iter()
                    .any(|s| s.name == name && s.kind == SampleKind::Histogram),
                "histogram_list name `{name}` missing from export()"
            );
        }
        let exported_hists: Vec<&str> = samples
            .iter()
            .filter(|s| s.kind == SampleKind::Histogram)
            .map(|s| s.name)
            .collect();
        for name in exported_hists {
            assert!(
                m.histogram_list().iter().any(|(n, _)| *n == name),
                "exported histogram `{name}` missing from histogram_list()"
            );
        }
    }

    #[test]
    fn export_has_occupancy_and_lane_depth_gauges() {
        let m = Metrics::new();
        m.batches_executed.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        m.decode_lane_depth.store(3, Ordering::Relaxed);
        let samples = m.export();
        let find = |name: &str| {
            samples
                .iter()
                .find(|s| s.name == name)
                .map(|s| (s.value, s.kind))
        };
        assert_eq!(
            find("batch_occupancy_total"),
            Some((2.5, SampleKind::Gauge))
        );
        assert_eq!(
            find("decode_lane_depth_total"),
            Some((3.0, SampleKind::Gauge))
        );
    }

    #[test]
    fn occupancy_math() {
        let m = Metrics::new();
        m.batches_executed.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.mean_batch_occupancy() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::new();
        m.submitted.store(17, Ordering::Relaxed);
        m.record_variant(crate::attention::AttentionVariant::Efficient);
        let s = m.summary();
        assert!(s.contains("submitted=17"));
        assert!(s.contains("efficient=1"));
    }

    #[test]
    fn summary_is_one_report_with_decode_counters() {
        let m = Metrics::new();
        m.record_variant(crate::attention::AttentionVariant::Direct);
        m.decode_steps.store(9, Ordering::Relaxed);
        m.promotions.store(2, Ordering::Relaxed);
        m.sessions_resident.store(3, Ordering::Relaxed);
        m.session_bytes.store(4096, Ordering::Relaxed);
        m.decode_latency.record(Duration::from_micros(50));
        let s = m.summary();
        for needle in [
            "direct=1",
            "steps=9",
            "promotions=2",
            "resident=3",
            "bytes=4096",
            "decode_latency:",
        ] {
            assert!(s.contains(needle), "summary missing {needle:?}:\n{s}");
        }
    }

    #[test]
    fn with_layers_sizes_gauges_and_reports_them() {
        let m = Metrics::with_layers(3);
        assert_eq!(m.layer_kv_sessions.len(), 3);
        assert_eq!(m.layer_recurrent_sessions.len(), 3);
        m.layer_kv_sessions[0].store(2, Ordering::Relaxed);
        m.layer_recurrent_sessions[2].store(1, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("layers: kv=[2, 0, 0] recurrent=[0, 0, 1]"), "{s}");
        let parsed = crate::util::json::Json::parse(&m.to_json().to_string()).unwrap();
        let layers = parsed.get("layers").and_then(|l| l.as_arr()).unwrap();
        assert_eq!(layers.len(), 3);
        assert_eq!(layers[0].get("kv").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(layers[2].get("recurrent").and_then(|x| x.as_f64()), Some(1.0));
    }

    fn exported_name_ok(name: &str) -> bool {
        let snake = name.starts_with(|c: char| c.is_ascii_lowercase())
            && name
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
        let suffixed =
            name.ends_with("_bytes") || name.ends_with("_us") || name.ends_with("_total");
        snake && suffixed
    }

    #[test]
    fn export_names_follow_convention() {
        let m = Metrics::with_layers(2);
        let samples = m.export();
        assert!(!samples.is_empty());
        for s in &samples {
            assert!(
                exported_name_ok(s.name),
                "metric `{}` violates the naming convention",
                s.name
            );
        }
    }

    #[test]
    fn export_reports_counters_gauges_and_histograms() {
        let m = Metrics::with_layers(2);
        m.submitted.store(5, Ordering::Relaxed);
        m.session_bytes.store(4096, Ordering::Relaxed);
        m.layer_kv_sessions[1].store(3, Ordering::Relaxed);
        m.decode_latency.record(Duration::from_micros(700));
        let samples = m.export();
        let find = |name: &str, stat: &str, layer: Option<usize>| {
            samples
                .iter()
                .find(|s| s.name == name && s.stat == stat && s.layer == layer)
                .map(|s| s.value)
        };
        assert_eq!(find("requests_submitted_total", "", None), Some(5.0));
        assert_eq!(find("session_state_bytes", "", None), Some(4096.0));
        assert_eq!(find("layer_kv_sessions_total", "", Some(1)), Some(3.0));
        assert_eq!(find("decode_latency_us", "count", None), Some(1.0));
        assert!(find("decode_latency_us", "p99", None).unwrap_or(0.0) >= 512.0);
    }

    #[test]
    fn export_reports_spill_series() {
        let m = Metrics::new();
        m.sessions_spilled.store(3, Ordering::Relaxed);
        m.sessions_restored.store(2, Ordering::Relaxed);
        m.spill_failures.store(1, Ordering::Relaxed);
        m.sessions_spilled_resident.store(1, Ordering::Relaxed);
        m.spill_file_bytes.store(2048, Ordering::Relaxed);
        m.restored_state_bytes.store(512, Ordering::Relaxed);
        m.restore_latency.record(Duration::from_micros(120));
        let samples = m.export();
        let find = |name: &str, stat: &str| {
            samples
                .iter()
                .find(|s| s.name == name && s.stat == stat)
                .map(|s| (s.value, s.kind))
        };
        assert_eq!(
            find("sessions_spilled_total", ""),
            Some((3.0, SampleKind::Counter))
        );
        assert_eq!(
            find("sessions_restored_total", ""),
            Some((2.0, SampleKind::Counter))
        );
        assert_eq!(
            find("spill_failures_total", ""),
            Some((1.0, SampleKind::Counter))
        );
        assert_eq!(
            find("spilled_sessions_total", ""),
            Some((1.0, SampleKind::Gauge))
        );
        assert_eq!(find("spill_file_bytes", ""), Some((2048.0, SampleKind::Gauge)));
        assert_eq!(
            find("restored_state_bytes", ""),
            Some((512.0, SampleKind::Gauge))
        );
        assert_eq!(
            find("restore_latency_us", "count"),
            Some((1.0, SampleKind::Histogram))
        );
        let s = m.summary();
        assert!(s.contains("spill: spilled=3 restored=2 failures=1"), "{s}");
    }

    #[test]
    fn to_json_roundtrips() {
        let m = Metrics::new();
        m.submitted.store(5, Ordering::Relaxed);
        m.decode_steps.store(7, Ordering::Relaxed);
        m.sessions_evicted.store(1, Ordering::Relaxed);
        m.latency.record(Duration::from_millis(3));
        let text = m.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("requests").and_then(|r| r.get("submitted")).and_then(|x| x.as_f64()),
            Some(5.0)
        );
        assert_eq!(
            parsed.get("decode").and_then(|r| r.get("steps")).and_then(|x| x.as_f64()),
            Some(7.0)
        );
        assert_eq!(
            parsed.get("sessions").and_then(|r| r.get("evicted")).and_then(|x| x.as_f64()),
            Some(1.0)
        );
        let count = parsed
            .get("latency")
            .and_then(|r| r.get("count"))
            .and_then(|x| x.as_f64());
        assert_eq!(count, Some(1.0));
    }
}

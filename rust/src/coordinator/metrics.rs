//! Serving metrics: counters and log-scale latency histograms,
//! lock-free on the hot path (atomics only).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log₂-bucketed latency histogram from 1 µs to ~1 hour.
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i µs, 2^(i+1) µs).
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

const HIST_BUCKETS: usize = 32;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Approximate quantile from the log₂ buckets (upper bound of the
    /// bucket containing the q-quantile).
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = ((n as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1u64 << HIST_BUCKETS)
    }
}

/// One exported scalar sample from [`Metrics::export`].
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Registered metric name: snake_case with a unit suffix
    /// (`_bytes`, `_us`, `_total`) — enforced by taylor-lint rule R5.
    pub name: &'static str,
    /// Derived statistic for histograms (`"p50"`, `"p99"`, `"mean"`,
    /// `"count"`); empty for plain counters and gauges.
    pub stat: &'static str,
    /// Per-layer gauge index (a label, not part of the name).
    pub layer: Option<usize>,
    pub value: f64,
}

/// Register a monotonic counter sample.
fn register_counter(out: &mut Vec<Sample>, name: &'static str, v: &AtomicU64) {
    out.push(Sample {
        name,
        stat: "",
        layer: None,
        value: v.load(Ordering::Relaxed) as f64,
    });
}

/// Register a gauge sample, optionally labelled with a layer index.
fn register_gauge(out: &mut Vec<Sample>, name: &'static str, layer: Option<usize>, value: u64) {
    out.push(Sample {
        name,
        stat: "",
        layer,
        value: value as f64,
    });
}

/// Register the derived samples of a latency histogram. The registered
/// base name carries the `_us` unit; the statistic rides in
/// [`Sample::stat`] (count is a raw sample count, not µs).
fn register_histogram(out: &mut Vec<Sample>, name: &'static str, h: &LatencyHistogram) {
    for (stat, value) in [
        ("count", h.count() as f64),
        ("mean", h.mean().as_micros() as f64),
        ("p50", h.quantile(0.5).as_micros() as f64),
        ("p99", h.quantile(0.99).as_micros() as f64),
    ] {
        out.push(Sample {
            name,
            stat,
            layer: None,
            value,
        });
    }
}

/// All engine metrics.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches_executed: AtomicU64,
    /// Sum of real requests across executed batches (for mean occupancy).
    pub batched_requests: AtomicU64,
    /// Padding rows executed (batch-slot waste).
    pub padding_rows: AtomicU64,
    /// End-to-end latency.
    pub latency: LatencyHistogram,
    /// Time spent waiting in the batcher.
    pub queue_wait: LatencyHistogram,
    /// Pure executable runtime.
    pub exec_time: LatencyHistogram,
    /// Per-variant request counts [direct, efficient, softmax].
    pub variant_counts: [AtomicU64; 3],
    // --- streaming decode (see `decode/`) ---
    /// Streams opened via `submit_stream`.
    pub streams_opened: AtomicU64,
    /// Streams closed via `close_stream`.
    pub streams_closed: AtomicU64,
    /// Decode steps served from resident session state (cache hits).
    pub decode_steps: AtomicU64,
    /// Decode steps that missed (session unknown/closed/evicted).
    pub decode_misses: AtomicU64,
    /// KV→recurrent promotions at the crossover.
    pub promotions: AtomicU64,
    /// Sessions LRU-evicted under the memory budget.
    pub sessions_evicted: AtomicU64,
    /// Gauge: sessions currently resident in the store.
    pub sessions_resident: AtomicU64,
    /// Gauge: bytes held by resident session state (all layers summed).
    pub session_bytes: AtomicU64,
    /// Per-token decode latency (submit → response).
    pub decode_latency: LatencyHistogram,
    /// Whole-model per-token step time (store.step only, excluding
    /// queueing).
    pub model_step_time: LatencyHistogram,
    /// Gauge per layer: resident sessions served on the KV branch.
    pub layer_kv_sessions: Vec<AtomicU64>,
    /// Gauge per layer: resident sessions served recurrent.
    pub layer_recurrent_sessions: Vec<AtomicU64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Metrics with per-layer branch-occupancy gauges sized for an
    /// `n_layers`-deep streaming model.
    pub fn with_layers(n_layers: usize) -> Self {
        Self {
            layer_kv_sessions: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
            layer_recurrent_sessions: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
            ..Self::default()
        }
    }

    pub fn record_variant(&self, v: crate::attention::AttentionVariant) {
        let idx = match v {
            crate::attention::AttentionVariant::Direct => 0,
            crate::attention::AttentionVariant::Efficient => 1,
            crate::attention::AttentionVariant::Softmax => 2,
        };
        self.variant_counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Mean requests per executed batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let batches = self.batches_executed.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
    }

    /// Snapshot of a gauge vector, e.g. `[3, 0, 1]`.
    fn gauge_vec(gauges: &[AtomicU64]) -> Vec<u64> {
        gauges.iter().map(|g| g.load(Ordering::Relaxed)).collect()
    }

    /// Flat, name-addressed export of every metric — the registration
    /// surface a scraper consumes. Names follow the machine-checked
    /// convention (snake_case, unit-suffixed); `summary()`/`to_json()`
    /// keep their legacy shapes for humans and the bench gate.
    pub fn export(&self) -> Vec<Sample> {
        let mut out = Vec::new();
        register_counter(&mut out, "requests_submitted_total", &self.submitted);
        register_counter(&mut out, "requests_completed_total", &self.completed);
        register_counter(&mut out, "requests_rejected_total", &self.rejected);
        register_counter(&mut out, "batches_executed_total", &self.batches_executed);
        register_counter(&mut out, "batched_requests_total", &self.batched_requests);
        register_counter(&mut out, "padding_rows_total", &self.padding_rows);
        register_counter(
            &mut out,
            "variant_direct_requests_total",
            &self.variant_counts[0],
        );
        register_counter(
            &mut out,
            "variant_efficient_requests_total",
            &self.variant_counts[1],
        );
        register_counter(
            &mut out,
            "variant_softmax_requests_total",
            &self.variant_counts[2],
        );
        register_counter(&mut out, "streams_opened_total", &self.streams_opened);
        register_counter(&mut out, "streams_closed_total", &self.streams_closed);
        register_counter(&mut out, "decode_steps_total", &self.decode_steps);
        register_counter(&mut out, "decode_misses_total", &self.decode_misses);
        register_counter(&mut out, "promotions_total", &self.promotions);
        register_counter(&mut out, "sessions_evicted_total", &self.sessions_evicted);
        register_gauge(
            &mut out,
            "resident_sessions_total",
            None,
            self.sessions_resident.load(Ordering::Relaxed),
        );
        register_gauge(
            &mut out,
            "session_state_bytes",
            None,
            self.session_bytes.load(Ordering::Relaxed),
        );
        for (l, g) in self.layer_kv_sessions.iter().enumerate() {
            register_gauge(
                &mut out,
                "layer_kv_sessions_total",
                Some(l),
                g.load(Ordering::Relaxed),
            );
        }
        for (l, g) in self.layer_recurrent_sessions.iter().enumerate() {
            register_gauge(
                &mut out,
                "layer_recurrent_sessions_total",
                Some(l),
                g.load(Ordering::Relaxed),
            );
        }
        register_histogram(&mut out, "request_latency_us", &self.latency);
        register_histogram(&mut out, "queue_wait_us", &self.queue_wait);
        register_histogram(&mut out, "exec_time_us", &self.exec_time);
        register_histogram(&mut out, "decode_latency_us", &self.decode_latency);
        register_histogram(&mut out, "model_step_time_us", &self.model_step_time);
        out
    }

    /// Human-readable summary block: one report covering the batch
    /// path, the per-variant split, and the streaming-decode state.
    pub fn summary(&self) -> String {
        format!(
            "requests: submitted={} completed={} rejected={}\n\
             batches: executed={} mean_occupancy={:.2} padding_rows={}\n\
             variants: direct={} efficient={} softmax={}\n\
             decode: steps={} misses={} promotions={}\n\
             sessions: opened={} closed={} evicted={} resident={} bytes={}\n\
             layers: kv={:?} recurrent={:?}\n\
             latency: mean={:?} p50={:?} p99={:?}\n\
             queue_wait: mean={:?} p99={:?}\n\
             exec: mean={:?} p99={:?}\n\
             decode_latency: mean={:?} p50={:?} p99={:?}\n\
             model_step: mean={:?} p50={:?} p99={:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches_executed.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.padding_rows.load(Ordering::Relaxed),
            self.variant_counts[0].load(Ordering::Relaxed),
            self.variant_counts[1].load(Ordering::Relaxed),
            self.variant_counts[2].load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.decode_misses.load(Ordering::Relaxed),
            self.promotions.load(Ordering::Relaxed),
            self.streams_opened.load(Ordering::Relaxed),
            self.streams_closed.load(Ordering::Relaxed),
            self.sessions_evicted.load(Ordering::Relaxed),
            self.sessions_resident.load(Ordering::Relaxed),
            self.session_bytes.load(Ordering::Relaxed),
            Self::gauge_vec(&self.layer_kv_sessions),
            Self::gauge_vec(&self.layer_recurrent_sessions),
            self.latency.mean(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.queue_wait.mean(),
            self.queue_wait.quantile(0.99),
            self.exec_time.mean(),
            self.exec_time.quantile(0.99),
            self.decode_latency.mean(),
            self.decode_latency.quantile(0.5),
            self.decode_latency.quantile(0.99),
            self.model_step_time.mean(),
            self.model_step_time.quantile(0.5),
            self.model_step_time.quantile(0.99),
        )
    }

    /// Machine-readable snapshot for benches and the server.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let n = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        let hist = |h: &LatencyHistogram| {
            Json::from_pairs(vec![
                ("count", Json::Num(h.count() as f64)),
                ("mean_us", Json::Num(h.mean().as_micros() as f64)),
                ("p50_us", Json::Num(h.quantile(0.5).as_micros() as f64)),
                ("p99_us", Json::Num(h.quantile(0.99).as_micros() as f64)),
            ])
        };
        Json::from_pairs(vec![
            (
                "requests",
                Json::from_pairs(vec![
                    ("submitted", n(&self.submitted)),
                    ("completed", n(&self.completed)),
                    ("rejected", n(&self.rejected)),
                ]),
            ),
            (
                "batches",
                Json::from_pairs(vec![
                    ("executed", n(&self.batches_executed)),
                    ("mean_occupancy", Json::Num(self.mean_batch_occupancy())),
                    ("padding_rows", n(&self.padding_rows)),
                ]),
            ),
            (
                "variants",
                Json::from_pairs(vec![
                    ("direct", n(&self.variant_counts[0])),
                    ("efficient", n(&self.variant_counts[1])),
                    ("softmax", n(&self.variant_counts[2])),
                ]),
            ),
            (
                "decode",
                Json::from_pairs(vec![
                    ("steps", n(&self.decode_steps)),
                    ("misses", n(&self.decode_misses)),
                    ("promotions", n(&self.promotions)),
                ]),
            ),
            (
                "sessions",
                Json::from_pairs(vec![
                    ("opened", n(&self.streams_opened)),
                    ("closed", n(&self.streams_closed)),
                    ("evicted", n(&self.sessions_evicted)),
                    ("resident", n(&self.sessions_resident)),
                    ("bytes", n(&self.session_bytes)),
                ]),
            ),
            (
                "layers",
                Json::Arr(
                    self.layer_kv_sessions
                        .iter()
                        .zip(&self.layer_recurrent_sessions)
                        .map(|(kv, rec)| {
                            Json::from_pairs(vec![
                                ("kv", Json::Num(kv.load(Ordering::Relaxed) as f64)),
                                ("recurrent", Json::Num(rec.load(Ordering::Relaxed) as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("latency", hist(&self.latency)),
            ("queue_wait", hist(&self.queue_wait)),
            ("exec", hist(&self.exec_time)),
            ("decode_latency", hist(&self.decode_latency)),
            ("model_step", hist(&self.model_step_time)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.quantile(0.5) <= Duration::from_millis(16));
        assert!(h.quantile(1.0) >= Duration::from_millis(100));
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.5), Duration::ZERO);
    }

    #[test]
    fn occupancy_math() {
        let m = Metrics::new();
        m.batches_executed.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.mean_batch_occupancy() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::new();
        m.submitted.store(17, Ordering::Relaxed);
        m.record_variant(crate::attention::AttentionVariant::Efficient);
        let s = m.summary();
        assert!(s.contains("submitted=17"));
        assert!(s.contains("efficient=1"));
    }

    #[test]
    fn summary_is_one_report_with_decode_counters() {
        let m = Metrics::new();
        m.record_variant(crate::attention::AttentionVariant::Direct);
        m.decode_steps.store(9, Ordering::Relaxed);
        m.promotions.store(2, Ordering::Relaxed);
        m.sessions_resident.store(3, Ordering::Relaxed);
        m.session_bytes.store(4096, Ordering::Relaxed);
        m.decode_latency.record(Duration::from_micros(50));
        let s = m.summary();
        for needle in [
            "direct=1",
            "steps=9",
            "promotions=2",
            "resident=3",
            "bytes=4096",
            "decode_latency:",
        ] {
            assert!(s.contains(needle), "summary missing {needle:?}:\n{s}");
        }
    }

    #[test]
    fn with_layers_sizes_gauges_and_reports_them() {
        let m = Metrics::with_layers(3);
        assert_eq!(m.layer_kv_sessions.len(), 3);
        assert_eq!(m.layer_recurrent_sessions.len(), 3);
        m.layer_kv_sessions[0].store(2, Ordering::Relaxed);
        m.layer_recurrent_sessions[2].store(1, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("layers: kv=[2, 0, 0] recurrent=[0, 0, 1]"), "{s}");
        let parsed = crate::util::json::Json::parse(&m.to_json().to_string()).unwrap();
        let layers = parsed.get("layers").and_then(|l| l.as_arr()).unwrap();
        assert_eq!(layers.len(), 3);
        assert_eq!(layers[0].get("kv").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(layers[2].get("recurrent").and_then(|x| x.as_f64()), Some(1.0));
    }

    fn exported_name_ok(name: &str) -> bool {
        let snake = name.starts_with(|c: char| c.is_ascii_lowercase())
            && name
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
        let suffixed =
            name.ends_with("_bytes") || name.ends_with("_us") || name.ends_with("_total");
        snake && suffixed
    }

    #[test]
    fn export_names_follow_convention() {
        let m = Metrics::with_layers(2);
        let samples = m.export();
        assert!(!samples.is_empty());
        for s in &samples {
            assert!(
                exported_name_ok(s.name),
                "metric `{}` violates the naming convention",
                s.name
            );
        }
    }

    #[test]
    fn export_reports_counters_gauges_and_histograms() {
        let m = Metrics::with_layers(2);
        m.submitted.store(5, Ordering::Relaxed);
        m.session_bytes.store(4096, Ordering::Relaxed);
        m.layer_kv_sessions[1].store(3, Ordering::Relaxed);
        m.decode_latency.record(Duration::from_micros(700));
        let samples = m.export();
        let find = |name: &str, stat: &str, layer: Option<usize>| {
            samples
                .iter()
                .find(|s| s.name == name && s.stat == stat && s.layer == layer)
                .map(|s| s.value)
        };
        assert_eq!(find("requests_submitted_total", "", None), Some(5.0));
        assert_eq!(find("session_state_bytes", "", None), Some(4096.0));
        assert_eq!(find("layer_kv_sessions_total", "", Some(1)), Some(3.0));
        assert_eq!(find("decode_latency_us", "count", None), Some(1.0));
        assert!(find("decode_latency_us", "p99", None).unwrap_or(0.0) >= 512.0);
    }

    #[test]
    fn to_json_roundtrips() {
        let m = Metrics::new();
        m.submitted.store(5, Ordering::Relaxed);
        m.decode_steps.store(7, Ordering::Relaxed);
        m.sessions_evicted.store(1, Ordering::Relaxed);
        m.latency.record(Duration::from_millis(3));
        let text = m.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("requests").and_then(|r| r.get("submitted")).and_then(|x| x.as_f64()),
            Some(5.0)
        );
        assert_eq!(
            parsed.get("decode").and_then(|r| r.get("steps")).and_then(|x| x.as_f64()),
            Some(7.0)
        );
        assert_eq!(
            parsed.get("sessions").and_then(|r| r.get("evicted")).and_then(|x| x.as_f64()),
            Some(1.0)
        );
        let count = parsed
            .get("latency")
            .and_then(|r| r.get("count"))
            .and_then(|x| x.as_f64());
        assert_eq!(count, Some(1.0));
    }
}

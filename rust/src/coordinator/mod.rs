//! L3 serving coordinator — the system contribution wrapped around the
//! paper's mechanism.
//!
//! Request path (python nowhere in sight):
//!
//! ```text
//! client ──submit──▶ [router: length bucket + variant selection]
//!        ──enqueue─▶ [dynamic batcher: per-(bucket) queues,
//!                     flush on max_batch or max_delay]
//!        ──execute─▶ [engine thread: PJRT executable for
//!                     (variant, bucket, batch-size)]
//!        ──reply───▶ per-request channel
//! ```
//!
//! The **variant selection** is the paper's "(and Back)": direct
//! `O(N²d)` for buckets below the crossover N₀(d), efficient `O(Nd³)`
//! above it (`attention::selector`). Because both variants compute the
//! same function with the same parameters, the router can switch per
//! bucket with zero accuracy cost — Section 6's closing argument,
//! realized as a scheduling policy.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineConfig};
pub use metrics::Metrics;
pub use request::{InferRequest, InferResponse, RequestError};
pub use router::{Route, Router};

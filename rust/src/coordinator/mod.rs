//! L3 serving coordinator — the system contribution wrapped around the
//! paper's mechanism.
//!
//! Request path (python nowhere in sight):
//!
//! ```text
//! client ──submit──▶ [router: length bucket + variant selection]
//!        ──enqueue─▶ [dynamic batcher: per-(bucket) queues,
//!                     flush on max_batch or max_delay]
//!        ──execute─▶ [engine thread: PJRT executable for
//!                     (variant, bucket, batch-size)]
//!        ──reply───▶ per-request channel
//! ```
//!
//! The **variant selection** is the paper's "(and Back)": direct
//! `O(N²d)` for buckets below the crossover N₀(d), efficient `O(Nd³)`
//! above it (`attention::selector`). Because both variants compute the
//! same function with the same parameters, the router can switch per
//! bucket with zero accuracy cost — Section 6's closing argument,
//! realized as a scheduling policy.
//!
//! The same crossover logic drives the **whole-model streaming decode**
//! path (`model/`, `decode/`): `Engine::submit_stream` +
//! `Engine::decode_step` thread one token embedding through every
//! transformer block of a resident per-layer state stack (KV cache
//! below N₀, recurrent moments above it — each layer crossing
//! independently), mixed into the engine cycle ahead of due prefill
//! batches via a bounded priority lane. Sessions evicted under the
//! memory budget answer their next step with a typed re-prefill error.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineConfig};
pub use metrics::Metrics;
pub use request::{
    DecodeRequest, DecodeResponse, InferRequest, InferResponse, RequestError, StreamStats,
};
pub use router::{Route, Router};

//! The serving engine: one executor thread owning all PJRT state
//! (client, compiled executables, parameter literals), fed through an
//! mpsc channel. Routing and batching decisions happen on that thread;
//! execution is serialized — the realistic model for a single device
//! stream, and it sidesteps the xla crate's `!Send` raw-pointer types.
//!
//! The executor is pluggable ([`BatchExecutor`]): production uses
//! [`RegistryExecutor`] over the AOT artifacts; tests inject mocks to
//! exercise the full request lifecycle without artifacts.
//!
//! Besides batched prefill/classification, the engine serves
//! **whole-model streaming decode** (see `model/`): `submit_stream`
//! opens a per-session, per-layer state stack on the engine thread and
//! `decode_step` threads one `[1, d_model]` token embedding through
//! every transformer block of the store's deterministic
//! [`crate::model::StreamingModel`]. Each layer's state promotes
//! KV→recurrent independently when the prefix crosses the selector's
//! N₀. Decode steps ride a priority lane mixed ahead of due prefill
//! batches each cycle. `submit_stream` returns a typed
//! [`SessionHandle`] (id + trace); decode/close accept any
//! [`AsSessionId`], so raw `u64` ids keep working one release.
//!
//! Under memory pressure the store spills LRU sessions to disk when
//! `decode.spill` is enabled and restores them transparently on the
//! next step; [`RequestError::NeedsReprefill`] only surfaces when
//! spill is off, its budget is exhausted, or a spill file fails
//! validation.

use crate::attention::selector::Selector;
use crate::attention::AttentionVariant;
use crate::coordinator::batcher::{BatchPolicy, DecodeLane, DynamicBatcher, PendingBatch};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{
    AsSessionId, DecodeRequest, DecodeResponse, InferRequest, InferResponse, RequestError,
    SessionHandle, StreamStats,
};
use crate::coordinator::router::{Route, Router};
use crate::data::batch::Buckets;
use crate::decode::DecodeConfig;
use crate::model::{SessionStore, StepMiss};
use crate::obs::recorder::{self, EventKind};
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Events included in an automatic flight-recorder dump.
const DUMP_EVENTS: usize = 64;

/// The last engine-surfaced typed error, kept as plain atomics so
/// recording never takes a lock on the serving path (lint rule R4
/// keeps this file Mutex-free). `seq` doubles as the presence flag
/// (0 = no error yet) and as the flight-recorder boundary, so the
/// dump shows only events up to the error.
#[derive(Default)]
struct LastError {
    /// Ring sequence number of the error event (0 = none yet).
    seq: AtomicU64,
    /// Error code (`obs::recorder::ERR_*`).
    code: AtomicU64,
    /// Trace ID of the failing request (0 when unknown).
    trace: AtomicU64,
    /// Subject id: decode session, or bucket for batch failures.
    subject: AtomicU64,
}

impl LastError {
    fn record(&self, code: u64, trace: u64, subject: u64) {
        let seq = recorder::record_error(code, trace, subject);
        self.code.store(code, Ordering::Relaxed);
        self.trace.store(trace, Ordering::Relaxed);
        self.subject.store(subject, Ordering::Relaxed);
        self.seq.store(seq, Ordering::Release);
    }

    fn dump(&self) -> Option<String> {
        let seq = self.seq.load(Ordering::Acquire);
        if seq == 0 {
            return None;
        }
        let code = self.code.load(Ordering::Relaxed);
        let json = Json::from_pairs(vec![
            (
                "error",
                Json::Str(recorder::error_code_label(code).to_string()),
            ),
            ("code", Json::Num(code as f64)),
            ("trace", Json::Num(self.trace.load(Ordering::Relaxed) as f64)),
            (
                "subject",
                Json::Num(self.subject.load(Ordering::Relaxed) as f64),
            ),
            ("seq", Json::Num(seq as f64)),
            ("events", recorder::dump_json(DUMP_EVENTS, seq)),
        ]);
        Some(json.to_string())
    }
}

/// Engine-internal failures. Surfaced to waiting requests as
/// [`RequestError::ExecFailed`] and to constructors as `anyhow` errors —
/// the engine thread never panics on the request path (lint rule R3).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The executor advertises no supported batch sizes.
    NoBatchSizes,
    /// A registry executor was configured with no sequence buckets.
    EmptyBuckets,
    /// An executable returned no output buffers.
    NoOutputs { artifact: String },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NoBatchSizes => write!(f, "executor advertises no batch sizes"),
            Self::EmptyBuckets => write!(f, "registry executor configured with no buckets"),
            Self::NoOutputs { artifact } => {
                write!(f, "artifact {artifact} returned no outputs")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// Executes one padded batch; implementations own the device state.
pub trait BatchExecutor {
    /// `tokens` is a rectangular (b, bucket) matrix (already padded to a
    /// supported batch size); returns one logits row per input row.
    fn execute(&mut self, route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String>;

    /// Batch sizes this executor supports, ascending (e.g. [1, 8]).
    fn batch_sizes(&self) -> &[usize];

    /// Token id used to pad rows/slots.
    fn pad_id(&self) -> i32 {
        0
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub buckets: Vec<usize>,
    /// Per-head dimension of the served model (selector input).
    pub head_dim: usize,
    pub policy: BatchPolicy,
    /// Backpressure: max requests in flight before rejecting.
    pub queue_limit: usize,
    /// Force one variant (None = adaptive selection — the default).
    pub forced_variant: Option<AttentionVariant>,
    /// Crossover policy (analytical N₀ by default; load a measured one
    /// via `Selector::from_json_file` — see `examples/crossover_sweep`).
    pub selector: Selector,
    /// Streaming-decode subsystem: session memory budget, per-head
    /// config, decode/prefill mixing (see `decode::DecodeConfig`).
    pub decode: DecodeConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            buckets: vec![128, 256, 512, 1024],
            head_dim: 16,
            policy: BatchPolicy::default(),
            queue_limit: 256,
            forced_variant: None,
            selector: Selector::analytical(),
            decode: DecodeConfig::default(),
        }
    }
}

impl EngineConfig {
    /// Start a validated config build from the defaults. Prefer this
    /// over a struct literal: `build()` rejects configurations the
    /// engine would otherwise accept and then misbehave on (zero byte
    /// budgets, a spill dir with spill disabled, ...).
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder {
            cfg: Self::default(),
        }
    }

    /// Check the invariants `build()` enforces. Public so config
    /// loaders (`config::ServerConfig`) can validate parsed files the
    /// same way hand-built configs are.
    pub fn validate(&self) -> Result<(), EngineConfigError> {
        if self.buckets.is_empty() {
            return Err(EngineConfigError::EmptyBuckets);
        }
        if self.decode.max_sessions == 0 {
            return Err(EngineConfigError::ZeroSessions);
        }
        if self.decode.max_session_bytes == 0 {
            return Err(EngineConfigError::ZeroByteBudget {
                what: "decode.max_session_bytes",
            });
        }
        if self.decode.spill.enabled && self.decode.spill.max_bytes == 0 {
            return Err(EngineConfigError::ZeroByteBudget {
                what: "decode.spill.max_bytes",
            });
        }
        if self.decode.spill.dir.is_some() && !self.decode.spill.enabled {
            return Err(EngineConfigError::SpillDirWithoutSpill);
        }
        if !self.decode.layer_taus.is_empty() && self.decode.layer_taus.len() != self.decode.n_layers
        {
            return Err(EngineConfigError::LayerTausMismatch {
                expected: self.decode.n_layers,
                got: self.decode.layer_taus.len(),
            });
        }
        Ok(())
    }
}

/// Why [`EngineConfig::validate`] rejected a configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineConfigError {
    /// A byte budget was explicitly zero — the engine would evict (or
    /// refuse to spill) every session immediately. Names the knob.
    ZeroByteBudget { what: &'static str },
    /// A spill directory was configured but spill is disabled; the
    /// dir would silently never be used.
    SpillDirWithoutSpill,
    /// `decode.max_sessions` of zero can hold no streams.
    ZeroSessions,
    /// No sequence buckets: the router could serve nothing.
    EmptyBuckets,
    /// `decode.layer_taus` was set but does not cover every layer.
    LayerTausMismatch { expected: usize, got: usize },
}

impl std::fmt::Display for EngineConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::ZeroByteBudget { what } => {
                write!(f, "byte budget {what} must be nonzero")
            }
            Self::SpillDirWithoutSpill => {
                write!(f, "spill dir configured but spill is disabled")
            }
            Self::ZeroSessions => write!(f, "decode.max_sessions must be nonzero"),
            Self::EmptyBuckets => write!(f, "no sequence buckets configured"),
            Self::LayerTausMismatch { expected, got } => {
                write!(f, "layer_taus covers {got} layers, model has {expected}")
            }
        }
    }
}

impl std::error::Error for EngineConfigError {}

/// Validating builder for [`EngineConfig`]; see [`EngineConfig::builder`].
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    pub fn buckets(mut self, buckets: Vec<usize>) -> Self {
        self.cfg.buckets = buckets;
        self
    }

    pub fn head_dim(mut self, d: usize) -> Self {
        self.cfg.head_dim = d;
        self
    }

    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn queue_limit(mut self, limit: usize) -> Self {
        self.cfg.queue_limit = limit;
        self
    }

    pub fn forced_variant(mut self, v: AttentionVariant) -> Self {
        self.cfg.forced_variant = Some(v);
        self
    }

    pub fn selector(mut self, selector: Selector) -> Self {
        self.cfg.selector = selector;
        self
    }

    /// Replace the whole decode sub-config (heads, layers, budgets).
    pub fn decode(mut self, decode: DecodeConfig) -> Self {
        self.cfg.decode = decode;
        self
    }

    pub fn max_sessions(mut self, n: usize) -> Self {
        self.cfg.decode.max_sessions = n;
        self
    }

    pub fn session_budget_bytes(mut self, bytes: u64) -> Self {
        self.cfg.decode.max_session_bytes = bytes;
        self
    }

    /// Turn the disk spill tier on or off (off by default).
    pub fn spill_enabled(mut self, enabled: bool) -> Self {
        self.cfg.decode.spill.enabled = enabled;
        self
    }

    /// Directory for spill files. Setting a dir does NOT enable spill;
    /// `build()` rejects a dir with spill disabled so the intent is
    /// always explicit.
    pub fn spill_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.cfg.decode.spill.dir = Some(dir.into());
        self
    }

    /// On-disk byte budget for the spill tier (defaults to
    /// [`crate::decode::SpillConfig::DEFAULT_MAX_BYTES`]).
    pub fn spill_budget_bytes(mut self, bytes: u64) -> Self {
        self.cfg.decode.spill.max_bytes = bytes;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<EngineConfig, EngineConfigError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

enum Msg {
    Infer(InferRequest, Sender<Result<InferResponse, RequestError>>),
    StreamOpen(u64, Sender<Result<SessionHandle, RequestError>>),
    Decode(DecodeRequest, DecodeResponder),
    StreamClose(u64, Sender<Result<StreamStats, RequestError>>),
    Shutdown,
}

/// Handle to a running engine. Cloneable; shuts down when the last
/// handle drops (via the explicit `shutdown` on Drop of the main one).
pub struct Engine {
    tx: Sender<Msg>,
    metrics: Arc<Metrics>,
    in_flight: Arc<AtomicUsize>,
    queue_limit: usize,
    next_id: AtomicU64,
    next_stream: AtomicU64,
    /// Expected decode token shape, `[1, d_model]`.
    decode_shape: [usize; 2],
    last_error: Arc<LastError>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Start with a custom executor (constructed ON the engine thread —
    /// xla types are not Send).
    pub fn start_with<F, E>(config: EngineConfig, make_executor: F) -> anyhow::Result<Self>
    where
        E: BatchExecutor,
        F: FnOnce() -> anyhow::Result<E> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::with_layers(config.decode.n_layers));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let last_error = Arc::new(LastError::default());
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let thread_metrics = Arc::clone(&metrics);
        let thread_in_flight = Arc::clone(&in_flight);
        let thread_last_error = Arc::clone(&last_error);
        let cfg = config.clone();
        let worker = std::thread::Builder::new()
            .name("ts-engine".into())
            .spawn(move || {
                let executor = match make_executor() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                engine_loop(
                    cfg,
                    executor,
                    rx,
                    thread_metrics,
                    thread_in_flight,
                    thread_last_error,
                );
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!("executor init failed: {e}"))?;
        Ok(Self {
            tx,
            metrics,
            in_flight,
            queue_limit: config.queue_limit,
            next_id: AtomicU64::new(1),
            next_stream: AtomicU64::new(1),
            decode_shape: [1, config.decode.heads * config.head_dim],
            last_error,
            worker: Some(worker),
        })
    }

    /// Submit a request; the returned receiver yields the response.
    pub fn submit(
        &self,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<InferResponse, RequestError>>, RequestError> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let current = self.in_flight.load(Ordering::Relaxed);
        if current >= self.queue_limit {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(RequestError::Overloaded {
                queued: current,
                limit: self.queue_limit,
            });
        }
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = channel();
        let req = InferRequest::new(id, tokens);
        self.tx
            .send(Msg::Infer(req, resp_tx))
            .map_err(|_| RequestError::Shutdown)?;
        Ok(resp_rx)
    }

    /// Submit and block for the result.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<InferResponse, RequestError> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| RequestError::Shutdown)?
    }

    /// Open a streaming decode session; returns its typed
    /// [`SessionHandle`] (session id + observability trace id). The
    /// session is resident on the engine thread until `close_stream`,
    /// or it is spilled/LRU-evicted under the configured memory
    /// budget.
    pub fn submit_stream(&self) -> Result<SessionHandle, RequestError> {
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = channel();
        self.tx
            .send(Msg::StreamOpen(id, resp_tx))
            .map_err(|_| RequestError::Shutdown)?;
        resp_rx.recv().map_err(|_| RequestError::Shutdown)?
    }

    /// Submit one decode step (the next token's embedding row,
    /// `[1, d_model]`); the returned receiver yields the final-block
    /// output after the token has passed through every layer.
    /// `session` is the [`SessionHandle`] from `submit_stream` (raw
    /// `u64` ids still work one release via [`AsSessionId`]).
    pub fn submit_decode(
        &self,
        session: impl AsSessionId,
        token: Tensor,
    ) -> Result<Receiver<Result<DecodeResponse, RequestError>>, RequestError> {
        if token.shape() != self.decode_shape.as_slice() {
            return Err(RequestError::BadDecodeShape {
                expected: self.decode_shape,
                got: token.shape().to_vec(),
            });
        }
        let (resp_tx, resp_rx) = channel();
        self.tx
            .send(Msg::Decode(
                DecodeRequest::new(session.session_id(), token),
                resp_tx,
            ))
            .map_err(|_| RequestError::Shutdown)?;
        Ok(resp_rx)
    }

    /// Submit a decode step and block for its output.
    pub fn decode_step(
        &self,
        session: impl AsSessionId,
        token: Tensor,
    ) -> Result<DecodeResponse, RequestError> {
        let rx = self.submit_decode(session, token)?;
        rx.recv().map_err(|_| RequestError::Shutdown)?
    }

    /// Close a stream and free its state (including any spill file);
    /// returns lifetime stats. Closing a spilled or evicted stream
    /// succeeds with `stats.evicted == true` reporting what was known
    /// at eviction time.
    pub fn close_stream(&self, session: impl AsSessionId) -> Result<StreamStats, RequestError> {
        let (resp_tx, resp_rx) = channel();
        self.tx
            .send(Msg::StreamClose(session.session_id(), resp_tx))
            .map_err(|_| RequestError::Shutdown)?;
        resp_rx.recv().map_err(|_| RequestError::Shutdown)?
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Prometheus text exposition: every exported counter and gauge,
    /// native histogram series, per-phase span timings, per-layer and
    /// per-branch decode step timing (see `obs::prometheus`).
    pub fn scrape(&self) -> String {
        crate::obs::prometheus::render(&self.metrics)
    }

    /// JSON dump of the whole flight-recorder ring (resident events,
    /// oldest first).
    pub fn flight_recorder_json(&self) -> String {
        recorder::dump_json(0, 0).to_string()
    }

    /// If the engine has surfaced a typed error, a JSON dump of it
    /// plus the flight-recorder events leading up to it. `None` until
    /// the first error.
    pub fn last_error_dump(&self) -> Option<String> {
        self.last_error.dump()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

type Responder = Sender<Result<InferResponse, RequestError>>;
type DecodeResponder = Sender<Result<DecodeResponse, RequestError>>;

fn engine_loop<E: BatchExecutor>(
    config: EngineConfig,
    mut executor: E,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    in_flight: Arc<AtomicUsize>,
    last_error: Arc<LastError>,
) {
    let mut router = Router::new(
        Buckets::new(config.buckets.clone()),
        config.selector.clone(),
        config.head_dim,
    );
    if let Some(v) = config.forced_variant {
        router = router.with_forced_variant(v);
    }
    let mut batcher = DynamicBatcher::new(config.policy);
    // ResponderId → waiting channel. Ids are request ids.
    let mut waiters: std::collections::HashMap<u64, Responder> = Default::default();
    // Streaming decode: session state lives here, on the engine thread.
    let mut store = SessionStore::new(
        config.decode.clone(),
        config.head_dim,
        config.selector.clone(),
        config.forced_variant,
    );
    let mut lane: DecodeLane<(DecodeRequest, DecodeResponder)> =
        DecodeLane::new(config.decode.max_steps_per_cycle);

    const IDLE: Duration = Duration::from_millis(50);
    let mut shutdown = false;
    while !shutdown {
        // Leftover decode work ⇒ poll without sleeping; otherwise wake
        // for the next batch deadline.
        let timeout = if lane.is_empty() {
            batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(IDLE)
        } else {
            Duration::ZERO
        };
        // Block for one message, then slurp everything already queued so
        // a cycle sees the full pending mix of prefill and decode.
        let mut msgs: Vec<Msg> = Vec::new();
        match rx.recv_timeout(timeout) {
            Ok(m) => msgs.push(m),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => shutdown = true,
        }
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        for msg in msgs {
            match msg {
                Msg::Infer(req, responder) => match router.route(req.tokens.len()) {
                    Ok(route) => {
                        let id = req.id;
                        waiters.insert(id, responder);
                        let ready = batcher.push(route, req, id, Instant::now());
                        for batch in ready {
                            run_batch(
                                &mut executor,
                                batch,
                                &mut waiters,
                                &metrics,
                                &in_flight,
                                &last_error,
                            );
                        }
                    }
                    Err(e) => {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        in_flight.fetch_sub(1, Ordering::Relaxed);
                        let _ = responder.send(Err(e));
                    }
                },
                Msg::StreamOpen(id, responder) => {
                    let evicted = store.open(id);
                    metrics.streams_opened.fetch_add(1, Ordering::Relaxed);
                    record_evictions(&evicted, &metrics);
                    update_session_gauges(&store, &metrics);
                    let handle = SessionHandle::new(id, store.trace_of(id).unwrap_or(0));
                    let _ = responder.send(Ok(handle));
                }
                Msg::Decode(req, responder) => {
                    let trace = store.trace_of(req.session).unwrap_or(0);
                    recorder::record_event(
                        EventKind::Enqueue,
                        trace,
                        req.session,
                        lane.pending() as u64 + 1,
                    );
                    lane.push((req, responder));
                    metrics
                        .decode_lane_depth
                        .store(lane.pending() as u64, Ordering::Relaxed);
                }
                Msg::StreamClose(id, responder) => {
                    let result = match store.close(id) {
                        Some(s) => {
                            metrics.streams_closed.fetch_add(1, Ordering::Relaxed);
                            Ok(StreamStats {
                                session: id,
                                tokens: s.tokens,
                                branches: s.branches,
                                bytes: s.bytes,
                                promoted_at: s.promoted_at,
                                trace: s.trace,
                                evicted: s.evicted,
                            })
                        }
                        None => Err(RequestError::UnknownSession { id }),
                    };
                    update_session_gauges(&store, &metrics);
                    let _ = responder.send(result);
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        // Decode steps run ahead of due batches, bounded per cycle so a
        // decode burst cannot starve prefill.
        for (req, responder) in lane.drain_cycle() {
            run_decode(&mut store, req, responder, &metrics, &last_error);
            metrics
                .decode_lane_depth
                .store(lane.pending() as u64, Ordering::Relaxed);
        }
        for batch in batcher.flush_due(Instant::now()) {
            run_batch(
                &mut executor,
                batch,
                &mut waiters,
                &metrics,
                &in_flight,
                &last_error,
            );
        }
    }
    // Drain on shutdown: execute what's queued so no request hangs.
    for (req, responder) in lane.drain_all() {
        run_decode(&mut store, req, responder, &metrics, &last_error);
    }
    metrics.decode_lane_depth.store(0, Ordering::Relaxed);
    for batch in batcher.flush_all() {
        run_batch(
            &mut executor,
            batch,
            &mut waiters,
            &metrics,
            &in_flight,
            &last_error,
        );
    }
    for (_, responder) in waiters.drain() {
        let _ = responder.send(Err(RequestError::Shutdown));
    }
    crate::obs::flush();
}

/// Count an eviction batch: every victim increments `sessions_evicted`;
/// the ones whose state survived to a spill file also increment
/// `sessions_spilled`.
fn record_evictions(evicted: &[crate::model::Eviction], metrics: &Metrics) {
    metrics
        .sessions_evicted
        .fetch_add(evicted.len() as u64, Ordering::Relaxed);
    let spilled = evicted.iter().filter(|e| e.spilled).count() as u64;
    metrics.sessions_spilled.fetch_add(spilled, Ordering::Relaxed);
}

fn update_session_gauges(store: &SessionStore, metrics: &Metrics) {
    metrics
        .sessions_resident
        .store(store.len() as u64, Ordering::Relaxed);
    metrics
        .session_bytes
        .store(store.resident_bytes(), Ordering::Relaxed);
    metrics
        .sessions_spilled_resident
        .store(store.spilled_sessions() as u64, Ordering::Relaxed);
    metrics
        .spill_file_bytes
        .store(store.spilled_bytes(), Ordering::Relaxed);
    let (kv, recurrent) = store.layer_occupancy();
    for (gauge, count) in metrics.layer_kv_sessions.iter().zip(kv) {
        gauge.store(count, Ordering::Relaxed);
    }
    for (gauge, count) in metrics.layer_recurrent_sessions.iter().zip(recurrent) {
        gauge.store(count, Ordering::Relaxed);
    }
}

/// Serve one whole-model decode step and record metrics.
fn run_decode(
    store: &mut SessionStore,
    req: DecodeRequest,
    responder: DecodeResponder,
    metrics: &Metrics,
    last_error: &LastError,
) {
    // Install the stream's trace ID for every span recorded below
    // (decode branch spans, per-layer block spans) — one trace per
    // stream, threaded end-to-end.
    let trace = store.trace_of(req.session).unwrap_or(0);
    let _trace_guard = crate::obs::trace_scope(trace);
    crate::obs::observe("lane.queue_wait", req.enqueued_at.elapsed(), trace);
    // Metrics/gauges are updated BEFORE the response is sent so a
    // blocking caller observes a consistent snapshot on return.
    let t_step = Instant::now();
    match store.step(req.session, &req.token) {
        Ok(outcome) => {
            metrics.model_step_time.record(t_step.elapsed());
            metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
            let promoted_layers = outcome
                .result
                .layers
                .iter()
                .filter(|l| l.promoted)
                .count() as u64;
            metrics.promotions.fetch_add(promoted_layers, Ordering::Relaxed);
            record_evictions(&outcome.evicted, metrics);
            if let Some(restore) = &outcome.restored {
                metrics.sessions_restored.fetch_add(1, Ordering::Relaxed);
                metrics
                    .restored_state_bytes
                    .fetch_add(restore.bytes, Ordering::Relaxed);
                metrics.restore_latency.record(restore.elapsed);
            }
            if promoted_layers > 0 {
                recorder::record_event(EventKind::Promote, trace, req.session, promoted_layers);
            }
            let latency = req.enqueued_at.elapsed();
            metrics.decode_latency.record(latency);
            update_session_gauges(store, metrics);
            crate::obs::flush();
            let _ = responder.send(Ok(DecodeResponse {
                session: req.session,
                step: outcome.result.len,
                output: outcome.result.output,
                promoted: promoted_layers > 0,
                layers: outcome.result.layers,
                latency,
                trace,
            }));
        }
        Err(miss) => {
            metrics.decode_misses.fetch_add(1, Ordering::Relaxed);
            update_session_gauges(store, metrics);
            // A failed restore surfaces as NeedsReprefill at the API —
            // the state is gone either way — but is counted and
            // flight-recorded separately so operators can tell
            // corruption from ordinary memory pressure.
            let (code, err) = match miss {
                StepMiss::Evicted => (
                    recorder::ERR_NEEDS_REPREFILL,
                    RequestError::NeedsReprefill { id: req.session },
                ),
                StepMiss::Unknown => (
                    recorder::ERR_UNKNOWN_SESSION,
                    RequestError::UnknownSession { id: req.session },
                ),
                StepMiss::SpillFailed(_) => {
                    metrics.spill_failures.fetch_add(1, Ordering::Relaxed);
                    (
                        recorder::ERR_SPILL_CORRUPT,
                        RequestError::NeedsReprefill { id: req.session },
                    )
                }
            };
            last_error.record(code, trace, req.session);
            crate::obs::flush();
            let _ = responder.send(Err(err));
        }
    }
}

/// Smallest supported executable batch that fits all `k` requests,
/// falling back to the largest supported batch when `k` exceeds it
/// (max_batch policy should match the largest artifact batch).
fn select_exec_batch(k: usize, sizes: &[usize]) -> Result<usize, EngineError> {
    sizes
        .iter()
        .copied()
        .find(|&b| b >= k)
        .or_else(|| sizes.iter().copied().max())
        .ok_or(EngineError::NoBatchSizes)
}

fn run_batch<E: BatchExecutor>(
    executor: &mut E,
    batch: PendingBatch,
    waiters: &mut std::collections::HashMap<u64, Responder>,
    metrics: &Metrics,
    in_flight: &AtomicUsize,
    last_error: &LastError,
) {
    let k = batch.requests.len();
    debug_assert!(k > 0);
    let route = batch.route;
    let exec_b = match select_exec_batch(k, executor.batch_sizes()) {
        Ok(b) => b,
        Err(e) => {
            // A misconfigured executor fails every waiter with a typed
            // error instead of panicking the engine thread.
            let trace0 = batch.requests.first().map(|(r, _)| r.trace).unwrap_or(0);
            last_error.record(recorder::ERR_EXEC_FAILED, trace0, route.bucket as u64);
            crate::obs::flush();
            let msg = e.to_string();
            for (_, responder_id) in batch.requests {
                in_flight.fetch_sub(1, Ordering::Relaxed);
                if let Some(responder) = waiters.remove(&responder_id) {
                    let _ = responder.send(Err(RequestError::ExecFailed(msg.clone())));
                }
            }
            return;
        }
    };
    let pad_id = executor.pad_id();

    // Assemble the padded token matrix.
    let mut tokens: Vec<Vec<i32>> = Vec::with_capacity(exec_b);
    for (req, _) in &batch.requests {
        tokens.push(crate::data::batch::fit_length(
            &req.tokens,
            route.bucket,
            pad_id,
        ));
    }
    while tokens.len() < exec_b {
        tokens.push(vec![pad_id; route.bucket]); // padding slots
    }
    metrics
        .padding_rows
        .fetch_add((exec_b - k) as u64, Ordering::Relaxed);

    let t_exec = Instant::now();
    // A batch carries many traces; the span is attributed to the first
    // request's trace (enough to find the batch in the recorder).
    let trace0 = batch.requests.first().map(|(r, _)| r.trace).unwrap_or(0);
    let exec_guard = crate::obs::trace_scope(trace0);
    let exec_span = crate::obs::span("engine.exec_batch");
    let result = executor.execute(route, &tokens);
    drop(exec_span);
    drop(exec_guard);
    let exec_time = t_exec.elapsed();
    metrics.exec_time.record(exec_time);
    metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_requests
        .fetch_add(k as u64, Ordering::Relaxed);

    match result {
        Ok(logits_rows) => {
            for (i, (req, responder_id)) in batch.requests.into_iter().enumerate() {
                let latency = req.enqueued_at.elapsed();
                metrics.latency.record(latency);
                metrics
                    .queue_wait
                    .record(latency.saturating_sub(exec_time));
                crate::obs::observe(
                    "batcher.queue_wait",
                    latency.saturating_sub(exec_time),
                    req.trace,
                );
                metrics.record_variant(route.variant);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                in_flight.fetch_sub(1, Ordering::Relaxed);
                if let Some(responder) = waiters.remove(&responder_id) {
                    crate::obs::flush();
                    let _ = responder.send(Ok(InferResponse {
                        id: req.id,
                        logits: logits_rows.get(i).cloned().unwrap_or_default(),
                        variant: route.variant,
                        bucket: route.bucket,
                        batch_size: k,
                        latency,
                    }));
                }
            }
        }
        Err(e) => {
            last_error.record(recorder::ERR_EXEC_FAILED, trace0, route.bucket as u64);
            crate::obs::flush();
            for (_, responder_id) in batch.requests {
                in_flight.fetch_sub(1, Ordering::Relaxed);
                if let Some(responder) = waiters.remove(&responder_id) {
                    let _ = responder.send(Err(RequestError::ExecFailed(e.clone())));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Production executor over the AOT registry
// ---------------------------------------------------------------------------

/// Executes batches through the AOT serving artifacts
/// (`serve_{variant}_infer_b{B}_n{N}`), with parameter literals
/// converted once and shared across executables.
pub struct RegistryExecutor {
    registry: crate::runtime::Registry,
    prefix: String,
    batch_sizes: Vec<usize>,
    /// Parameter literals (identical across serve artifacts by
    /// construction — same seed, shape-independent init).
    params: Vec<xla::Literal>,
}

impl RegistryExecutor {
    pub fn new(
        artifacts_dir: impl AsRef<std::path::Path>,
        prefix: &str,
        buckets: &[usize],
        batch_sizes: &[usize],
    ) -> anyhow::Result<Self> {
        let runtime = crate::runtime::Runtime::cpu()?;
        let registry = crate::runtime::Registry::open(runtime, artifacts_dir)?;
        // Preload every (variant, bucket, batch) executable now so the
        // request path never pays compile latency.
        for variant in ["direct", "efficient"] {
            for &n in buckets {
                for &b in batch_sizes {
                    let name = format!("{prefix}_{variant}_infer_b{b}_n{n}");
                    registry.load(&name)?;
                }
            }
        }
        let &b0 = batch_sizes
            .first()
            .ok_or_else(|| anyhow::anyhow!("{}", EngineError::NoBatchSizes))?;
        let &n0 = buckets
            .first()
            .ok_or_else(|| anyhow::anyhow!("{}", EngineError::EmptyBuckets))?;
        let param_src = format!("{prefix}_efficient_infer_b{b0}_n{n0}");
        let params = registry
            .load_params(&param_src)?
            .iter()
            .map(|t| crate::runtime::literal::tensor_to_literal(t))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self {
            registry,
            prefix: prefix.to_string(),
            batch_sizes: batch_sizes.to_vec(),
            params,
        })
    }

    fn artifact_name(&self, route: Route, b: usize) -> String {
        format!(
            "{}_{}_infer_b{}_n{}",
            self.prefix,
            route.variant.name(),
            b,
            route.bucket
        )
    }
}

impl BatchExecutor for RegistryExecutor {
    fn execute(&mut self, route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        let name = self.artifact_name(route, tokens.len());
        let exe = self.registry.load(&name).map_err(|e| e.to_string())?;
        // §Perf L3: parameters are passed by reference — `execute` takes
        // `Borrow<Literal>`, so the ~N_params × size copy that an owned
        // input vector would cost never happens (see EXPERIMENTS.md).
        let tokens_lit =
            crate::runtime::literal::tokens_to_literal(tokens).map_err(|e| e.to_string())?;
        let inputs: Vec<&xla::Literal> = self
            .params
            .iter()
            .chain(std::iter::once(&tokens_lit))
            .collect();
        let outputs = exe.run(&inputs).map_err(|e| e.to_string())?;
        let first = outputs.first().ok_or_else(|| {
            EngineError::NoOutputs {
                artifact: name.clone(),
            }
            .to_string()
        })?;
        let logits =
            crate::runtime::literal::literal_to_tensor(first).map_err(|e| e.to_string())?;
        let (b, c) = (logits.shape()[0], logits.shape()[1]);
        Ok((0..b)
            .map(|i| logits.data()[i * c..(i + 1) * c].to_vec())
            .collect())
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }
}

/// Deep-copy a literal (shape + raw bytes).
pub fn clone_literal(lit: &xla::Literal) -> anyhow::Result<xla::Literal> {
    let shape = lit.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let data = lit.to_vec::<f32>()?;
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock executor: logits = [sum of tokens, bucket, batch index, variant].
    struct MockExecutor {
        batch_sizes: Vec<usize>,
        fail: bool,
        delay: Duration,
        executed_batches: Arc<AtomicUsize>,
    }

    impl BatchExecutor for MockExecutor {
        fn execute(
            &mut self,
            route: Route,
            tokens: &[Vec<i32>],
        ) -> Result<Vec<Vec<f32>>, String> {
            if self.fail {
                return Err("mock failure".into());
            }
            std::thread::sleep(self.delay);
            self.executed_batches.fetch_add(1, Ordering::Relaxed);
            Ok(tokens
                .iter()
                .map(|row| {
                    vec![
                        row.iter().sum::<i32>() as f32,
                        route.bucket as f32,
                        match route.variant {
                            AttentionVariant::Direct => 0.0,
                            AttentionVariant::Efficient => 1.0,
                            AttentionVariant::Softmax => 2.0,
                        },
                    ]
                })
                .collect())
        }

        fn batch_sizes(&self) -> &[usize] {
            &self.batch_sizes
        }
    }

    fn mock_engine(config: EngineConfig) -> (Engine, Arc<AtomicUsize>) {
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let engine = Engine::start_with(config, move || {
            Ok(MockExecutor {
                batch_sizes: vec![1, 8],
                fail: false,
                delay: Duration::ZERO,
                executed_batches: c2,
            })
        })
        .unwrap();
        (engine, counter)
    }

    #[test]
    fn single_request_roundtrip() {
        let (engine, _) = mock_engine(EngineConfig::default());
        let resp = engine.infer(vec![1, 2, 3]).unwrap();
        assert_eq!(resp.logits[0], 6.0);
        assert_eq!(resp.bucket, 128);
        assert_eq!(resp.variant, AttentionVariant::Direct); // 128 < N0(16)
        assert_eq!(engine.in_flight(), 0);
    }

    #[test]
    fn long_sequence_routes_efficient() {
        let (engine, _) = mock_engine(EngineConfig::default());
        let resp = engine.infer(vec![1; 700]).unwrap();
        assert_eq!(resp.bucket, 1024);
        assert_eq!(resp.variant, AttentionVariant::Efficient);
    }

    #[test]
    fn too_long_rejected() {
        let (engine, _) = mock_engine(EngineConfig::default());
        let err = engine.infer(vec![1; 5000]).unwrap_err();
        assert!(matches!(err, RequestError::TooLong { .. }));
    }

    #[test]
    fn batches_aggregate_concurrent_requests() {
        let (engine, executed) = mock_engine(EngineConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(30),
            },
            ..Default::default()
        });
        // Fire 8 same-bucket requests; they should coalesce into one
        // batch once max_batch is hit.
        let rxs: Vec<_> = (0..8)
            .map(|i| engine.submit(vec![i as i32; 100]).unwrap())
            .collect();
        let responses: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        assert!(responses.iter().all(|r| r.bucket == 128));
        assert_eq!(responses.iter().map(|r| r.batch_size).max(), Some(8));
        assert_eq!(executed.load(Ordering::Relaxed), 1, "one fused batch");
    }

    #[test]
    fn delay_flush_for_lone_request() {
        let (engine, _) = mock_engine(EngineConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(10),
            },
            ..Default::default()
        });
        let t0 = Instant::now();
        let resp = engine.infer(vec![1, 2]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(9), "waited for delay");
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn failure_propagates_to_all_requests() {
        let engine = Engine::start_with(EngineConfig::default(), move || {
            Ok(MockExecutor {
                batch_sizes: vec![1, 8],
                fail: true,
                delay: Duration::ZERO,
                executed_batches: Arc::new(AtomicUsize::new(0)),
            })
        })
        .unwrap();
        let err = engine.infer(vec![1, 2, 3]).unwrap_err();
        assert!(matches!(err, RequestError::ExecFailed(_)));
        assert_eq!(engine.in_flight(), 0);
    }

    #[test]
    fn backpressure_rejects_above_limit() {
        let (engine, _) = mock_engine(EngineConfig {
            queue_limit: 4,
            policy: BatchPolicy {
                max_batch: 64,
                max_delay: Duration::from_millis(200),
            },
            ..Default::default()
        });
        let mut oks = 0;
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..20 {
            match engine.submit(vec![i; 10]) {
                Ok(rx) => {
                    oks += 1;
                    rxs.push(rx);
                }
                Err(RequestError::Overloaded { .. }) => rejected += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected > 0, "expected overload rejections");
        assert!(oks >= 4);
        for rx in rxs {
            let _ = rx.recv();
        }
    }

    #[test]
    fn forced_variant_respected() {
        let (engine, _) = mock_engine(EngineConfig {
            forced_variant: Some(AttentionVariant::Efficient),
            ..Default::default()
        });
        let resp = engine.infer(vec![1; 10]).unwrap();
        assert_eq!(resp.variant, AttentionVariant::Efficient);
    }

    #[test]
    fn metrics_populated() {
        let (engine, _) = mock_engine(EngineConfig::default());
        for _ in 0..5 {
            engine.infer(vec![1; 50]).unwrap();
        }
        let m = engine.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), 5);
        assert!(m.latency.count() == 5);
        assert!(m.summary().contains("completed=5"));
    }

    #[test]
    fn shutdown_drains_pending() {
        let (engine, _) = mock_engine(EngineConfig {
            policy: BatchPolicy {
                max_batch: 64,
                max_delay: Duration::from_secs(10), // won't flush by time
            },
            ..Default::default()
        });
        let rx = engine.submit(vec![1, 2, 3]).unwrap();
        drop(engine); // shutdown must flush, not orphan
        let result = rx.recv().unwrap();
        assert!(result.is_ok(), "drained on shutdown: {result:?}");
    }

    #[test]
    fn select_exec_batch_picks_smallest_fit() {
        assert_eq!(select_exec_batch(3, &[1, 4, 8]), Ok(4));
        assert_eq!(select_exec_batch(1, &[1, 4, 8]), Ok(1));
        assert_eq!(
            select_exec_batch(9, &[1, 4, 8]),
            Ok(8),
            "overflow falls back to the largest supported batch"
        );
        assert_eq!(select_exec_batch(1, &[]), Err(EngineError::NoBatchSizes));
    }

    #[test]
    fn empty_batch_sizes_fail_typed_not_panic() {
        let engine = Engine::start_with(EngineConfig::default(), move || {
            Ok(MockExecutor {
                batch_sizes: vec![],
                fail: false,
                delay: Duration::ZERO,
                executed_batches: Arc::new(AtomicUsize::new(0)),
            })
        })
        .unwrap();
        let err = engine.infer(vec![1, 2, 3]).unwrap_err();
        match err {
            RequestError::ExecFailed(msg) => {
                assert!(msg.contains("no batch sizes"), "{msg}")
            }
            other => panic!("expected ExecFailed, got {other:?}"),
        }
        assert_eq!(engine.in_flight(), 0, "waiter accounting still balances");
    }

    #[test]
    fn engine_error_display() {
        assert!(EngineError::NoBatchSizes.to_string().contains("no batch sizes"));
        assert!(EngineError::EmptyBuckets.to_string().contains("buckets"));
        let e = EngineError::NoOutputs {
            artifact: "serve_direct_infer_b1_n128".into(),
        };
        assert!(e.to_string().contains("serve_direct_infer_b1_n128"));
    }

    // --- whole-model streaming decode ---

    #[test]
    fn decode_stream_parity_and_promotion() {
        let (heads, d) = (2usize, 16usize);
        // Calibrated crossover at N₀=8 so every layer starts on the KV
        // branch and promotes mid-stream.
        let decode = DecodeConfig {
            heads,
            tau: 1.0,
            ..DecodeConfig::default()
        };
        let n_layers = decode.n_layers;
        let (engine, _) = mock_engine(EngineConfig {
            head_dim: d,
            selector: Selector::calibrated(vec![(d, 8.0)]),
            decode: decode.clone(),
            ..Default::default()
        });
        // Same deterministic weights the engine's store builds.
        let model = crate::model::StreamingModel::new(
            crate::model::ModelConfig::from_decode(&decode, d),
        );
        let dm = model.d_model();
        let steps = 20usize;
        let x = Tensor::randn(&[steps, dm], 424_242);
        let batch = model.forward_batch(&x, &vec![Some(8); n_layers]);

        let sid = engine.submit_stream().unwrap();
        for t in 0..steps {
            let token = Tensor::new(&[1, dm], x.row(t).to_vec());
            let resp = engine.decode_step(sid, token).unwrap();
            assert_eq!(resp.step, t + 1);
            assert_eq!(resp.promoted, t + 1 == 8, "promotion exactly at N₀");
            assert_eq!(resp.layers.len(), n_layers);
            for (l, ls) in resp.layers.iter().enumerate() {
                assert_eq!(ls.promoted, t + 1 == 8, "layer {l} step {}", t + 1);
                let expect = if t + 1 < 8 {
                    AttentionVariant::Direct
                } else {
                    AttentionVariant::Efficient
                };
                assert_eq!(ls.branch, expect, "layer {l} step {}", t + 1);
            }
            assert_eq!(
                resp.output.as_slice(),
                batch.row(t),
                "streaming row {} must match the batch forward pass",
                t + 1
            );
        }
        let m = engine.metrics();
        assert_eq!(m.decode_steps.load(Ordering::Relaxed), steps as u64);
        assert_eq!(
            m.promotions.load(Ordering::Relaxed),
            n_layers as u64,
            "every layer promoted once"
        );
        assert_eq!(m.streams_opened.load(Ordering::Relaxed), 1);
        assert_eq!(m.decode_latency.count(), steps as u64);
        assert_eq!(m.model_step_time.count(), steps as u64);
        assert!(m.sessions_resident.load(Ordering::Relaxed) == 1);
        assert!(m.session_bytes.load(Ordering::Relaxed) > 0);
        // Per-layer gauges: the one session is recurrent at every layer.
        // (Checked before close_stream — closing zeroes the gauges.)
        for l in 0..n_layers {
            assert_eq!(m.layer_kv_sessions[l].load(Ordering::Relaxed), 0);
            assert_eq!(m.layer_recurrent_sessions[l].load(Ordering::Relaxed), 1);
        }

        let stats = engine.close_stream(sid).unwrap();
        assert_eq!(stats.tokens, steps);
        assert_eq!(stats.branches, vec![AttentionVariant::Efficient; n_layers]);
        assert_eq!(stats.promoted_at, vec![Some(8); n_layers]);
        assert!(!stats.evicted, "closed while resident");
        assert_eq!(stats.trace, sid.trace(), "handle carries the stream trace");
        assert_eq!(m.streams_closed.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_resident.load(Ordering::Relaxed), 0);
        // Double close and post-close decode both miss as Unknown
        // (closed normally, not evicted).
        assert!(matches!(
            engine.close_stream(sid),
            Err(RequestError::UnknownSession { .. })
        ));
        let err = engine
            .decode_step(sid, Tensor::randn(&[1, dm], 1))
            .unwrap_err();
        assert!(matches!(err, RequestError::UnknownSession { .. }));
        assert_eq!(m.decode_misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn decode_shape_validated_at_submit() {
        // Default config: heads=4, head_dim=16 ⇒ d_model=64. A raw u64
        // session id still names a session (one-release compat shim).
        let (engine, _) = mock_engine(EngineConfig::default());
        let bad = Tensor::randn(&[2, 16], 1);
        let err = engine.submit_decode(1u64, bad).unwrap_err();
        assert!(matches!(
            err,
            RequestError::BadDecodeShape {
                expected: [1, 64],
                ..
            }
        ));
    }

    #[test]
    fn stream_capacity_evicts_lru() {
        let (engine, _) = mock_engine(EngineConfig {
            decode: DecodeConfig {
                heads: 1,
                max_sessions: 1,
                ..DecodeConfig::default()
            },
            ..Default::default()
        });
        let mk = |seed| Tensor::randn(&[1, 16], seed);
        let s1 = engine.submit_stream().unwrap();
        engine.decode_step(s1, mk(1)).unwrap();
        let s2 = engine.submit_stream().unwrap();
        // s1 was evicted to make room for s2: its state is gone and the
        // caller must re-prefill (typed error, not a silent fresh state).
        let err = engine.decode_step(s1, mk(4)).unwrap_err();
        assert_eq!(err, RequestError::NeedsReprefill { id: s1.id() });
        engine.decode_step(s2, mk(7)).unwrap();
        let m = engine.metrics();
        assert_eq!(m.sessions_evicted.load(Ordering::Relaxed), 1);
        assert_eq!(m.streams_opened.load(Ordering::Relaxed), 2);
        assert_eq!(m.decode_misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn builder_validates_config() {
        assert!(EngineConfig::builder().build().is_ok());
        assert_eq!(
            EngineConfig::builder().buckets(vec![]).build().unwrap_err(),
            EngineConfigError::EmptyBuckets
        );
        assert_eq!(
            EngineConfig::builder().max_sessions(0).build().unwrap_err(),
            EngineConfigError::ZeroSessions
        );
        assert_eq!(
            EngineConfig::builder()
                .session_budget_bytes(0)
                .build()
                .unwrap_err(),
            EngineConfigError::ZeroByteBudget {
                what: "decode.max_session_bytes"
            }
        );
        assert_eq!(
            EngineConfig::builder()
                .spill_enabled(true)
                .spill_budget_bytes(0)
                .build()
                .unwrap_err(),
            EngineConfigError::ZeroByteBudget {
                what: "decode.spill.max_bytes"
            }
        );
        assert_eq!(
            EngineConfig::builder().spill_dir("/tmp/x").build().unwrap_err(),
            EngineConfigError::SpillDirWithoutSpill
        );
        let ok = EngineConfig::builder()
            .spill_enabled(true)
            .spill_dir("/tmp/x")
            .build()
            .unwrap();
        assert!(ok.decode.spill.enabled);
        assert!(matches!(
            EngineConfig::builder()
                .decode(DecodeConfig {
                    layer_taus: vec![1.0],
                    ..DecodeConfig::default()
                })
                .build(),
            Err(EngineConfigError::LayerTausMismatch {
                expected: 2,
                got: 1
            })
        ));
        assert!(EngineConfigError::SpillDirWithoutSpill
            .to_string()
            .contains("spill"));
        assert!(EngineConfigError::ZeroByteBudget {
            what: "decode.spill.max_bytes"
        }
        .to_string()
        .contains("decode.spill.max_bytes"));
    }

    #[test]
    fn stream_spills_and_restores_transparently() {
        let dir =
            std::env::temp_dir().join(format!("ts-engine-spill-{}", std::process::id()));
        let cfg = EngineConfig::builder()
            .decode(DecodeConfig {
                heads: 1,
                max_sessions: 1,
                ..DecodeConfig::default()
            })
            .spill_enabled(true)
            .spill_dir(dir.clone())
            .build()
            .unwrap();
        let (engine, _) = mock_engine(cfg);
        let mk = |seed| Tensor::randn(&[1, 16], seed);

        let s1 = engine.submit_stream().unwrap();
        engine.decode_step(s1, mk(1)).unwrap();
        let s2 = engine.submit_stream().unwrap();
        let m = engine.metrics();
        // s1 was pushed out by s2 — but to disk, not destroyed.
        assert_eq!(m.sessions_evicted.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_spilled.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_spilled_resident.load(Ordering::Relaxed), 1);
        assert!(m.spill_file_bytes.load(Ordering::Relaxed) > 0);

        // Touching s1 restores it transparently (and spills s2 in turn):
        // the step continues exactly where the stream left off.
        let resp = engine.decode_step(s1, mk(2)).unwrap();
        assert_eq!(resp.step, 2, "restored stream continues its prefix");
        assert_eq!(resp.trace, s1.trace(), "trace survives the round trip");
        assert_eq!(m.sessions_restored.load(Ordering::Relaxed), 1);
        assert!(m.restored_state_bytes.load(Ordering::Relaxed) > 0);
        assert_eq!(m.restore_latency.count(), 1);
        assert_eq!(m.decode_misses.load(Ordering::Relaxed), 0, "no NeedsReprefill");

        // Closing the now-spilled s2 succeeds with what was known and
        // cleans up its spill file.
        let stats = engine.close_stream(s2).unwrap();
        assert!(stats.evicted, "closed from the spilled state");
        assert_eq!(stats.tokens, 0);
        assert_eq!(m.sessions_spilled_resident.load(Ordering::Relaxed), 0);
        assert_eq!(m.spill_file_bytes.load(Ordering::Relaxed), 0);
        let stats = engine.close_stream(s1).unwrap();
        assert!(!stats.evicted);
        drop(engine);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn decode_mixes_with_prefill() {
        let (engine, _) = mock_engine(EngineConfig {
            decode: DecodeConfig {
                heads: 1,
                ..DecodeConfig::default()
            },
            ..Default::default()
        });
        let sid = engine.submit_stream().unwrap();
        let mut decode_rxs = Vec::new();
        let mut infer_rxs = Vec::new();
        for t in 0..5u64 {
            decode_rxs.push(
                engine
                    .submit_decode(sid, Tensor::randn(&[1, 16], t))
                    .unwrap(),
            );
            infer_rxs.push(engine.submit(vec![1; 50]).unwrap());
        }
        for rx in decode_rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        for rx in infer_rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let m = engine.metrics();
        assert_eq!(m.decode_steps.load(Ordering::Relaxed), 5);
        assert_eq!(m.completed.load(Ordering::Relaxed), 5);
        assert_eq!(engine.close_stream(sid).unwrap().tokens, 5);
    }
}

//! Request router: length → bucket, bucket → attention variant.
//!
//! The variant decision implements the paper's "(and Back)" with the
//! crossover machinery from `attention::selector`; admission control
//! rejects sequences beyond the largest bucket up front so they never
//! consume queue space.

use crate::attention::selector::Selector;
use crate::attention::AttentionVariant;
use crate::coordinator::request::RequestError;
use crate::data::batch::Buckets;

/// Routing decision for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    /// Padded sequence length (one of the configured buckets).
    pub bucket: usize,
    /// Attention implementation to use for this bucket.
    pub variant: AttentionVariant,
}

/// Length-bucket router with a pluggable variant policy.
#[derive(Clone, Debug)]
pub struct Router {
    buckets: Buckets,
    selector: Selector,
    /// Per-head dimension of the served model (d = d_emb / h).
    head_dim: usize,
    /// Force a fixed variant (overrides the selector) — used by benches
    /// and the ablation examples.
    forced: Option<AttentionVariant>,
}

impl Router {
    pub fn new(buckets: Buckets, selector: Selector, head_dim: usize) -> Self {
        Self {
            buckets,
            selector,
            head_dim,
            forced: None,
        }
    }

    /// Force every request onto one variant.
    pub fn with_forced_variant(mut self, v: AttentionVariant) -> Self {
        self.forced = Some(v);
        self
    }

    pub fn buckets(&self) -> &Buckets {
        &self.buckets
    }

    /// Route a request by raw sequence length.
    pub fn route(&self, len: usize) -> Result<Route, RequestError> {
        if len == 0 {
            return Err(RequestError::Empty);
        }
        let bucket = self
            .buckets
            .select(len)
            .ok_or(RequestError::TooLong {
                len,
                max: self.buckets.largest(),
            })?;
        let variant = self
            .forced
            .unwrap_or_else(|| self.selector.select(bucket, self.head_dim));
        Ok(Route { bucket, variant })
    }

    /// The crossover length the router is operating with (diagnostics).
    pub fn crossover(&self) -> f64 {
        self.selector.crossover(self.head_dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{run, Config, Gen};

    fn router() -> Router {
        // d=16 → N0 ≈ 271: buckets 128/256 → direct, 512/1024 → efficient.
        Router::new(
            Buckets::new(vec![128, 256, 512, 1024]),
            Selector::analytical(),
            16,
        )
    }

    #[test]
    fn routes_short_to_direct_long_to_efficient() {
        let r = router();
        assert_eq!(
            r.route(100).unwrap(),
            Route { bucket: 128, variant: AttentionVariant::Direct }
        );
        assert_eq!(
            r.route(256).unwrap(),
            Route { bucket: 256, variant: AttentionVariant::Direct }
        );
        assert_eq!(
            r.route(300).unwrap(),
            Route { bucket: 512, variant: AttentionVariant::Efficient }
        );
        assert_eq!(
            r.route(1000).unwrap(),
            Route { bucket: 1024, variant: AttentionVariant::Efficient }
        );
    }

    #[test]
    fn rejects_empty_and_too_long() {
        let r = router();
        assert_eq!(r.route(0), Err(RequestError::Empty));
        assert_eq!(
            r.route(2000),
            Err(RequestError::TooLong { len: 2000, max: 1024 })
        );
    }

    #[test]
    fn forced_variant_overrides() {
        let r = router().with_forced_variant(AttentionVariant::Efficient);
        assert_eq!(r.route(10).unwrap().variant, AttentionVariant::Efficient);
    }

    #[test]
    fn prop_bucket_fits_and_variant_monotone() {
        let r = router();
        run(
            Config::default().cases(256),
            Gen::usize_range(1, 1024),
            move |&len| {
                let route = r.route(len).unwrap();
                // bucket fits
                if route.bucket < len {
                    return false;
                }
                // variant is monotone in bucket: if efficient at this
                // bucket, all larger buckets are efficient too.
                if route.variant == AttentionVariant::Efficient {
                    r.buckets()
                        .sizes()
                        .iter()
                        .filter(|&&b| b > route.bucket)
                        .all(|&b| {
                            r.route(b).unwrap().variant == AttentionVariant::Efficient
                        })
                } else {
                    true
                }
            },
        );
    }
}

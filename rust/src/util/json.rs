//! Minimal JSON: value model, recursive-descent parser, and writer.
//!
//! Used for the `artifacts/manifest.json` interchange with the python AOT
//! pipeline, server/experiment configs, and metrics dumps. Supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null); numbers are held as `f64` which is lossless for the
//! integer ranges we use (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order), which keeps artifact manifests
/// diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- constructors ----------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---------- accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` style access; returns `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Insert into an object (panics on non-objects — construction-time API).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Convenience: array of usize.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---------- parsing ----------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------- writing ----------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting bound: malformed (or adversarial) deeply-nested input must
/// come back as a `JsonError`, not blow the stack — the parser feeds on
/// external config/manifest/metrics files.
const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let result = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        };
        self.depth -= 1;
        result
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let code = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let low = self.hex4()?;
                                    // A non-low-surrogate here must error:
                                    // `low - 0xDC00` would underflow.
                                    if !(0xDC00..0xE000).contains(&low) {
                                        return Err(self.err("bad surrogate pair"));
                                    }
                                    let combined = 0x10000
                                        + (((code - 0xD800) as u32) << 10)
                                        + (low - 0xDC00) as u32;
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(code as u32)
                                    .ok_or_else(|| self.err("bad \\u escape"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf-8"))?;
                    let c = text.chars().next().ok_or_else(|| self.err("bad utf-8"))?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let code = u16::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn malformed_inputs_error_instead_of_panicking() {
        // Every case here previously either panicked, could panic on a
        // debug-mode overflow (lone/bad surrogate pairs), or relied on
        // an internal unwrap — all must surface as `JsonError` now.
        for bad in [
            "",
            "{",
            "}",
            "[",
            "]",
            "{\"a\"}",
            "{\"a\":}",
            "{:1}",
            "[1 2]",
            "[,]",
            "{\"k\":1,}",
            "\"\\q\"",
            "\"\\u12\"",
            "\"\\ud800\"",
            // High surrogate followed by a non-low-surrogate escape:
            // the pair combiner must reject it, not underflow.
            "\"\\ud800\\u0041\"",
            "\"\\ud800\\ud801\"",
            "-",
            "+1",
            "0x10",
            "tru",
            "nulll",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must fail to parse");
        }
    }

    #[test]
    fn deep_nesting_is_rejected_not_stack_overflowed() {
        let deep = "[".repeat(5000) + &"]".repeat(5000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.message.contains("deep"), "got: {}", err.message);
        // Reasonable nesting still parses.
        let ok = "[".repeat(100) + "1" + &"]".repeat(100);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn valid_surrogate_pairs_still_decode() {
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"shapes": [[4, 128, 16], [4, 128, 16]], "name": "tsa", "ok": true, "pi": 3.25}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(16513.0);
        assert_eq!(v.to_string(), "16513");
        let v = Json::Num(0.5);
        assert_eq!(v.to_string(), "0.5");
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![1, 2, 3]));
        let v = Json::parse("[1, -2]").unwrap();
        assert_eq!(v.as_usize_vec(), None);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("n", Json::Num(128.0))
            .set("variant", Json::Str("efficient".into()));
        assert_eq!(o.get("n").unwrap().as_usize(), Some(128));
    }
}

//! Little-endian binary codec for state serialization.
//!
//! The spill/restore tier (`model/spill.rs`) persists decode state to
//! disk and the streaming parity guarantee demands the round trip be
//! **bit-exact**: floats are encoded as their raw IEEE-754 bit
//! patterns (`to_bits`/`from_bits`), never formatted or re-rounded, so
//! an f64 Taylor-moment accumulator restores to the identical value.
//! Readers return typed [`CodecError`]s instead of panicking — decoded
//! bytes come from disk and may be arbitrarily corrupt.

/// Why a decode failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The buffer ended before the value was complete.
    Truncated,
    /// An enum/option tag byte had no defined meaning.
    BadTag { what: &'static str, tag: u8 },
    /// A decoded value violated a structural invariant.
    Invalid { what: &'static str },
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated => write!(f, "byte stream truncated"),
            Self::BadTag { what, tag } => write!(f, "bad tag {tag} for {what}"),
            Self::Invalid { what } => write!(f, "invalid encoded value: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Append-only little-endian writer over a byte vector.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Bit-exact f32: raw IEEE-754 bits, no rounding.
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Bit-exact f64: raw IEEE-754 bits, no rounding.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_f32_slice(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f32(v);
        }
    }

    pub fn put_f64_slice(&mut self, vs: &[f64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f64(v);
        }
    }
}

/// Cursor-based little-endian reader with typed errors.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self.pos.checked_add(n).ok_or(CodecError::Truncated)?;
        let slice = self.buf.get(self.pos..end).ok_or(CodecError::Truncated)?;
        self.pos = end;
        Ok(slice)
    }

    pub fn get_u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        let mut a = [0u8; 4];
        a.copy_from_slice(b);
        Ok(u32::from_le_bytes(a))
    }

    pub fn get_u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub fn get_f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    pub fn get_f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Length-prefixed f32 slice; `max_len` bounds the allocation so a
    /// corrupt length cannot trigger an absurd reservation.
    pub fn get_f32_vec(&mut self, max_len: usize) -> Result<Vec<f32>, CodecError> {
        let n = self.get_u64()? as usize;
        if n > max_len || n > self.remaining() / 4 {
            return Err(CodecError::Invalid { what: "f32 slice length" });
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32()?);
        }
        Ok(out)
    }

    /// Length-prefixed f64 slice; `max_len` bounds the allocation.
    pub fn get_f64_vec(&mut self, max_len: usize) -> Result<Vec<f64>, CodecError> {
        let n = self.get_u64()? as usize;
        if n > max_len || n > self.remaining() / 8 {
            return Err(CodecError::Invalid { what: "f64 slice length" });
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }
}

/// FNV-1a 64-bit hash — the spill-file payload checksum. Not
/// cryptographic; it detects torn writes and bit rot, which is all the
/// restore path needs before trusting a file.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_bit_exact() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX - 3);
        w.put_f32(f32::from_bits(0x7f80_0001)); // signalling NaN pattern
        w.put_f64(-0.0);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_f32().unwrap().to_bits(), 0x7f80_0001);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_slices() {
        let f32s = vec![1.5f32, -2.25, 0.1];
        let f64s = vec![1.0f64 / 3.0, f64::MIN_POSITIVE];
        let mut w = ByteWriter::new();
        w.put_f32_slice(&f32s);
        w.put_f64_slice(&f64s);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_f32_vec(16).unwrap(), f32s);
        assert_eq!(r.get_f64_vec(16).unwrap(), f64s);
    }

    #[test]
    fn truncated_reads_are_typed() {
        let mut r = ByteReader::new(&[1, 2]);
        assert_eq!(r.get_u64().unwrap_err(), CodecError::Truncated);
    }

    #[test]
    fn oversized_slice_length_rejected() {
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX); // absurd length prefix
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(matches!(
            r.get_f64_vec(1024).unwrap_err(),
            CodecError::Invalid { .. }
        ));
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
    }
}

//! Deterministic pseudo-random number generation.
//!
//! Two small, well-studied generators implemented from their reference
//! algorithms: [`SplitMix64`] (Steele et al., used for seeding) and
//! [`Pcg64`] (O'Neill's PCG XSL-RR 128/64, the workhorse). No external
//! crates; everything in the repository that needs randomness (data
//! generators, property tests, initializers) goes through here so runs
//! are reproducible from a single seed.

/// SplitMix64: a tiny 64-bit generator mainly used to expand a user seed
/// into state for larger generators.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64: 128-bit LCG state with an xorshift-rotate output
/// permutation. Passes BigCrush; plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Construct from a single 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, bias-free).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi)` (half-open; handy for indexing).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generators here are not the bottleneck).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Pick a uniformly-random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli trial with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fork an independent stream (distinct increment ⇒ distinct sequence).
    pub fn fork(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 from the reference implementation.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn pcg_deterministic_and_distinct_seeds() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        let mut c = Pcg64::new(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Pcg64::new(5);
        let mut f = a.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| f.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut rng = Pcg64::new(13);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let x = rng.range_u64(3, 6);
            assert!((3..=6).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}

//! Substrate utilities implemented in-tree (the build environment has no
//! crates.io access beyond the `xla` closure): PRNG, JSON, thread pool,
//! statistics, and CLI parsing.

pub mod bytes;
pub mod cli;
pub mod json;
pub mod numeric;
pub mod rng;
pub mod stats;
pub mod threadpool;

//! A fixed-size worker thread pool over `std::sync::mpsc`.
//!
//! Stands in for an async runtime on the L3 hot path: the coordinator
//! engine submits batch-execution jobs here, and request completion is
//! signalled back through per-request channels. Panic-safe (a panicking
//! job poisons neither the pool nor other jobs) and shuts down gracefully
//! on drop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed worker pool. Jobs run FIFO across workers.
pub struct ThreadPool {
    sender: Sender<Message>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let active = Arc::new(AtomicUsize::new(0));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                let active = Arc::clone(&active);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("ts-worker-{i}"))
                    .spawn(move || worker_loop(rx, active, queued))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            sender,
            workers,
            active,
            queued,
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs currently executing.
    pub fn active_jobs(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Jobs waiting in the queue (approximate; used for backpressure).
    pub fn queued_jobs(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.sender
            .send(Message::Run(Box::new(job)))
            .expect("pool closed");
    }

    /// Submit a job and get a handle to its result.
    pub fn submit<T, F>(&self, job: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        self.execute(move || {
            // Receiver may be dropped; ignore.
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }

    /// Block until queue is empty and no job is running (test helper;
    /// polls because mpsc has no completion signal).
    pub fn wait_idle(&self) {
        while self.queued_jobs() > 0 || self.active_jobs() > 0 {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block for the result. Returns `None` if the job panicked.
    pub fn join(self) -> Option<T> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Message>>>,
    active: Arc<AtomicUsize>,
    queued: Arc<AtomicUsize>,
) {
    loop {
        let msg = {
            let guard = rx.lock().expect("pool receiver poisoned");
            // lint: allow(lock-across-channel) -- the Mutex exists only to hand the single consumer end to one idle worker at a time; blocking recv under it IS the handoff protocol, and the guard drops before the job runs
            guard.recv()
        };
        match msg {
            Ok(Message::Run(job)) => {
                queued.fetch_sub(1, Ordering::SeqCst);
                active.fetch_add(1, Ordering::SeqCst);
                // Contain panics so one bad job doesn't kill the worker.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                active.fetch_sub(1, Ordering::SeqCst);
                if result.is_err() {
                    // Job panicked; its JobHandle sender was dropped, which
                    // the waiter observes as None.
                }
            }
            Ok(Message::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_results() {
        let pool = ThreadPool::new(2);
        let handles: Vec<_> = (0..10u64).map(|i| pool.submit(move || i * i)).collect();
        let results: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(results, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(1);
        let bad = pool.submit(|| -> u32 { panic!("boom") });
        assert_eq!(bad.join(), None);
        // Pool still works afterwards on the same (single) worker.
        let good = pool.submit(|| 7u32);
        assert_eq!(good.join(), Some(7));
    }

    #[test]
    fn parallel_speedup_is_possible() {
        // Not a timing assertion — just checks concurrent execution works:
        // two sleeping jobs on two workers overlap.
        let pool = ThreadPool::new(2);
        let t0 = std::time::Instant::now();
        let a = pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(50)));
        let b = pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(50)));
        a.join();
        b.join();
        assert!(t0.elapsed() < std::time::Duration::from_millis(95));
    }

    #[test]
    fn shutdown_on_drop_completes_queued_work() {
        let counter = Arc::new(AtomicU32::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
        } // drop here
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }
}

//! Descriptive statistics for benchmarking and experiment reporting:
//! online moments, percentiles, trimmed means, and least-squares fits
//! (linear and parabola — the paper extrapolates memory curves for
//! d ∈ {64, 128} in Fig. 2 by fitting a parabola; we do the same).

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile with linear interpolation; `q` in [0, 1]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Mean after trimming `trim_frac` of samples from each tail — robust
/// timing statistic (drops warmup spikes and scheduler noise).
pub fn trimmed_mean(xs: &[f64], trim_frac: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((v.len() as f64) * trim_frac).floor() as usize;
    let kept = &v[k..v.len() - k.min(v.len() - 1)];
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// Least-squares straight line `y = a + b x`; returns `(a, b)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Least-squares parabola `y = a + b x + c x²`; returns `(a, b, c)`.
/// Solves the 3×3 normal equations by Gaussian elimination.
pub fn parabola_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 3);
    let n = xs.len() as f64;
    let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
    let (mut t0, mut t1, mut t2) = (0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        let x2 = x * x;
        s1 += x;
        s2 += x2;
        s3 += x2 * x;
        s4 += x2 * x2;
        t0 += y;
        t1 += x * y;
        t2 += x2 * y;
    }
    let mut m = [[n, s1, s2, t0], [s1, s2, s3, t1], [s2, s3, s4, t2]];
    // Gaussian elimination with partial pivoting.
    for col in 0..3 {
        let pivot = (col..3)
            .max_by(|&a, &b| m[a][col].abs().partial_cmp(&m[b][col].abs()).unwrap())
            .unwrap();
        m.swap(col, pivot);
        let p = m[col][col];
        assert!(p.abs() > 1e-12, "singular normal equations");
        for row in 0..3 {
            if row != col {
                let f = m[row][col] / p;
                for k in col..4 {
                    m[row][k] -= f * m[col][k];
                }
            }
        }
    }
    (m[0][3] / m[0][0], m[1][3] / m[1][1], m[2][3] / m[2][2])
}

/// First crossing point of two sampled curves: smallest x where
/// `ya(x) >= yb(x)` flips relative to the start, linearly interpolated.
/// Used to locate the empirical N̂₀ / N̂₁ intersections of Fig. 2.
pub fn crossover(xs: &[f64], ya: &[f64], yb: &[f64]) -> Option<f64> {
    assert_eq!(xs.len(), ya.len());
    assert_eq!(xs.len(), yb.len());
    if xs.len() < 2 {
        return None;
    }
    // Establish the initial ordering from the first non-tied sample so
    // curves that start equal (e.g. both zero at N=0) don't produce a
    // spurious crossover at the origin.
    let mut start = 0;
    while start < xs.len() && ya[start] == yb[start] {
        start += 1;
    }
    if start >= xs.len() {
        return None;
    }
    let sign0 = (ya[start] - yb[start]).signum();
    for i in start + 1..xs.len() {
        let diff = ya[i] - yb[i];
        if diff == 0.0 {
            return Some(xs[i]);
        }
        if diff.signum() != sign0 {
            // Interpolate between i-1 and i.
            let d0 = ya[i - 1] - yb[i - 1];
            let d1 = diff;
            let t = d0 / (d0 - d1);
            return Some(xs[i - 1] + t * (xs[i] - xs[i - 1]));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = Moments::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            m.add(x);
        }
        assert_eq!(m.count(), 8);
        assert!((m.mean() - 5.0).abs() < 1e-12);
        assert!((m.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(m.min(), 2.0);
        assert_eq!(m.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.25), 2.0);
        // interpolation
        let xs = [1.0, 2.0];
        assert!((percentile(&xs, 0.5) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let xs = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 100.0, 0.0];
        let tm = trimmed_mean(&xs, 0.1);
        assert!((tm - 1.0).abs() < 1e-12, "tm={tm}");
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn parabola_fit_recovers_quadratic() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 0.3).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 1.0 - 2.0 * x + 0.25 * x * x).collect();
        let (a, b, c) = parabola_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-6, "a={a}");
        assert!((b + 2.0).abs() < 1e-6, "b={b}");
        assert!((c - 0.25).abs() < 1e-6, "c={c}");
    }

    #[test]
    fn crossover_of_linear_and_quadratic() {
        // quadratic y = x² vs linear y = 4x cross at x = 4
        // (the x = 0 tie must be skipped, not reported).
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let quad: Vec<f64> = xs.iter().map(|x| x * x).collect();
        let lin: Vec<f64> = xs.iter().map(|x| 4.0 * x).collect();
        let x = crossover(&xs, &quad, &lin).unwrap();
        assert!((x - 4.0).abs() < 1e-9, "x={x}");
    }

    #[test]
    fn crossover_none_when_no_crossing() {
        let xs = [0.0, 1.0, 2.0];
        let a = [1.0, 2.0, 3.0];
        let b = [0.0, 0.5, 1.0];
        assert_eq!(crossover(&xs, &a, &b), None);
    }
}

//! Tiny command-line parser for the binaries and examples.
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments. Typed getters with defaults keep call sites terse.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of argument strings.
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of usizes, e.g. `--ns 128,256,512`.
    pub fn usize_list(&self, name: &str) -> Option<Vec<usize>> {
        self.get(name).map(|v| {
            v.split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name} must be comma-separated integers"))
                })
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn flags_options_positionals() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--n=512", "input.txt"]);
        assert_eq!(a.positional(), &["serve".to_string(), "input.txt".to_string()]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.usize_or("n", 0), 512);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("steps", 100), 100);
        assert_eq!(a.f64_or("lr", 1e-3), 1e-3);
        assert_eq!(a.str_or("variant", "auto"), "auto");
    }

    #[test]
    fn lists() {
        let a = parse(&["--ns", "128, 256,512"]);
        assert_eq!(a.usize_list("ns"), Some(vec![128, 256, 512]));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--check"]);
        assert!(a.flag("check"));
    }
}

//! Shared numeric guards for the Taylor-softmax normalization.
//!
//! The paper's normalization scheme (Section 3.3) keeps every
//! denominator strictly positive in exact arithmetic — the per-token
//! Taylor weight is `1 + s + s²/2 = ½(s+1)² + ½ > 0` — but the serving
//! path must not rely on a `debug_assert!` that compiles out in
//! release builds. Every division by a moment/sum goes through
//! [`guard_denom`] (or carries an explicit `// lint: allow` hatch),
//! which taylor-lint rule R2 enforces across `attention/`, `decode/`,
//! and `model/`.

/// Smallest denominator magnitude admitted into a normalization
/// division. Matches the `‖·‖.max(1e-12)` guard used for the q/k row
/// norms, so guarded and unguarded-in-exact-arithmetic paths round
/// identically whenever the denominator is healthy.
pub const DENOM_EPS: f64 = 1e-12;

/// Clamp an f64 normalizer away from zero before dividing.
///
/// A no-op for every healthy Taylor-softmax denominator (they are
/// ≥ α⁴ ≥ 1 by construction), so adding the guard cannot perturb the
/// streaming-vs-batch bit-exactness invariant.
#[inline]
pub fn guard_denom(x: f64) -> f64 {
    x.max(DENOM_EPS)
}

/// f32 counterpart of [`guard_denom`] for single-precision paths.
#[inline]
pub fn guard_denom_f32(x: f32) -> f32 {
    x.max(DENOM_EPS as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_denominators_pass_through_unchanged() {
        for x in [1.0f64, 16.0, 1e-6, 123.456] {
            assert_eq!(guard_denom(x), x);
        }
        assert_eq!(guard_denom_f32(2.5), 2.5);
    }

    #[test]
    fn degenerate_denominators_are_clamped() {
        assert_eq!(guard_denom(0.0), DENOM_EPS);
        assert_eq!(guard_denom(-1.0), DENOM_EPS);
        assert_eq!(guard_denom(f64::NEG_INFINITY), DENOM_EPS);
        assert_eq!(guard_denom_f32(0.0), DENOM_EPS as f32);
        assert!(guard_denom(1e-13) == DENOM_EPS);
    }

    #[test]
    fn division_through_guard_is_finite() {
        let y = 1.0 / guard_denom(0.0);
        assert!(y.is_finite());
    }
}

//! The paper's analytical cost models (Section 4 + Appendix A).
//!
//! Everything here is closed-form and hardware-agnostic: FLOP counts
//! (Eqs. 5–6), memory entry counts (Eq. 8 and the direct-variant
//! expression), the speed/memory transition points N₀/N₁ (Eqs. 7/9),
//! the multi-head scaling laws of Section 4.3 with their optima ĥ₀/ĥ₁
//! (Eqs. 10–12, App. A.2/A.3), and a TPU roofline/VMEM estimator for
//! the Pallas BlockSpecs (DESIGN.md §Hardware-Adaptation).

pub mod flops;
pub mod memory;
pub mod mhsa;
pub mod roofline;
pub mod transitions;

//! Efficiency transition points (paper Eqs. 7 & 9, Table 2) and the
//! optimal-head-count analysis (Section 4.3, Appendix A.2/A.3).

/// Speed transition point N₀(d) (Eq. 7): the sequence length where
/// direct- and efficient-TaylorShift need equal FLOPs:
/// `N₀ = (4d³ + 10d² + 9d + 4) / (4d + 6)`.
pub fn n0(d: u64) -> f64 {
    let d = d as f64;
    (4.0 * d.powi(3) + 10.0 * d.powi(2) + 9.0 * d + 4.0) / (4.0 * d + 6.0)
}

/// Upper bound from Eq. 7: `N₀ ≤ d² + d + ¾`.
pub fn n0_bound(d: u64) -> f64 {
    let d = d as f64;
    d * d + d + 0.75
}

/// Memory transition point N₁(d) (Eq. 9): where peak entry counts of
/// both implementations agree:
/// `N₁ = ¼ [d² + 2d + 1 + √(d⁴ + 12d³ + 14d² + 4d + 1)]`.
pub fn n1(d: u64) -> f64 {
    let d = d as f64;
    let disc = d.powi(4) + 12.0 * d.powi(3) + 14.0 * d.powi(2) + 4.0 * d + 1.0;
    0.25 * (d * d + 2.0 * d + 1.0 + disc.sqrt())
}

/// Upper bound from Eq. 9: `N₁ ≤ ½d² + 2d + ½`.
pub fn n1_bound(d: u64) -> f64 {
    let d = d as f64;
    0.5 * d * d + 2.0 * d + 0.5
}

/// The per-head dimension `d ≈ 0.52` that minimizes ops_eff[MHSA]
/// (Eq. 10/12): the unique positive root of `9d³ + 10d² = 4`, via the
/// Cardano solution of Appendix A.2 with `α = ∛(3374 + 54√3561)`.
///
/// NOTE: the paper's final printed formula, `d = α/27 + (100/729)α⁻¹ −
/// 10/27`, carries a transcription slip: with `y = α/27` the second
/// Cardano term is `100/(729 y) = 100/(27 α)`, not `100/(729 α)`. Only
/// the corrected form satisfies `9d³ + 10d² = 4` and yields the paper's
/// own quoted `d ≈ 0.52` (the printed form gives 0.33). We assert the
/// cubic in tests.
pub fn d_star_ops() -> f64 {
    let alpha = (3374.0 + 54.0 * 3561.0_f64.sqrt()).cbrt();
    alpha / 27.0 + 100.0 / (27.0 * alpha) - 10.0 / 27.0
}

/// Optimal head count for FLOPs: `ĥ₀ ≈ d_emb / 0.52` (Section 4.3).
/// Larger than any admissible h ≤ d_emb ⇒ "more heads is always faster"
/// for efficient-TaylorShift within the allowed range.
pub fn h0_hat(d_emb: u64) -> f64 {
    d_emb as f64 / d_star_ops()
}

/// Appendix A.3: the memory-optimal per-head dimension satisfies
/// `N = 2d³ + (N+1)d²`, which forces `d < 1` and hence `ĥ₁ > d_emb`.
/// Solve for d given N by bisection (the LHS−RHS is monotone in d>0).
pub fn d_star_memory(n: u64) -> f64 {
    let n = n as f64;
    let f = |d: f64| 2.0 * d.powi(3) + (n + 1.0) * d * d - n;
    let (mut lo, mut hi) = (0.0_f64, 1.0_f64);
    debug_assert!(f(lo) < 0.0 && f(hi) > 0.0);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if f(mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Optimal head count for memory: `ĥ₁ = d_emb / d_star_memory(N) > d_emb`.
pub fn h1_hat(d_emb: u64, n: u64) -> f64 {
    d_emb as f64 / d_star_memory(n)
}

/// Paper Table 2, regenerated: (d, N₀ rounded, N₁ rounded) rows for the
/// typical head dimensions.
pub fn table2() -> Vec<(u64, u64, u64)> {
    [8u64, 16, 32, 64, 128]
        .iter()
        .map(|&d| (d, n0(d).round() as u64, n1(d).round() as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{flops, memory};

    #[test]
    fn table2_d128_matches_paper() {
        // The only fully-legible Table 2 column in the source: d = 128
        // gives N0 = 16513, N1 = 8446.
        assert_eq!(n0(128).round() as u64, 16513);
        assert_eq!(n1(128).round() as u64, 8446);
    }

    #[test]
    fn n0_is_flop_equality_point() {
        for d in [8u64, 16, 32, 64, 128] {
            let n = n0(d);
            let below = n.floor() as u64;
            let above = n.ceil() as u64 + 1;
            assert!(
                flops::ops_direct(below, d) <= flops::ops_efficient(below, d),
                "d={d}"
            );
            assert!(
                flops::ops_direct(above, d) >= flops::ops_efficient(above, d),
                "d={d}"
            );
        }
    }

    #[test]
    fn n1_is_entry_equality_point() {
        for d in [8u64, 16, 32, 64, 128] {
            let n = n1(d);
            // Eq. 9 derivation: entries equal at N1 exactly (real root).
            let e_t = |n: f64| (d * d) as f64 * (d + 1) as f64 + 2.0 * (d as f64) * n
                + (d + 1) as f64 * n
                + (d * d) as f64 * n;
            let e_d = |n: f64| (d as f64) * n + 2.0 * n * n;
            assert!((e_t(n) - e_d(n)).abs() / e_d(n) < 1e-9, "d={d}");
        }
    }

    #[test]
    fn bounds_hold() {
        for d in [1u64, 2, 8, 16, 32, 64, 128, 256] {
            assert!(n0(d) <= n0_bound(d) + 1e-9, "d={d}");
            assert!(n1(d) <= n1_bound(d) + 1e-9, "d={d}");
        }
    }

    #[test]
    fn n1_well_below_n0() {
        // Paper: "N1 is considerably smaller than N0". The gap widens
        // with d (ratio → ½); at d=8 it is ≈ 0.64.
        for d in [8u64, 16, 32, 64, 128] {
            assert!(n1(d) < 0.75 * n0(d), "d={d}: {} vs {}", n1(d), n0(d));
        }
        assert!(n1(128) < 0.52 * n0(128));
    }

    #[test]
    fn d_star_is_cubic_root() {
        let d = d_star_ops();
        assert!((9.0 * d.powi(3) + 10.0 * d.powi(2) - 4.0).abs() < 1e-6);
        assert!((d - 0.52).abs() < 0.005, "paper quotes ≈0.52, got {d}");
    }

    #[test]
    fn h0_hat_exceeds_demb() {
        // ⇒ within {1..d_emb} more heads always reduce ops.
        for demb in [64u64, 192, 256, 348, 512] {
            assert!(h0_hat(demb) > demb as f64);
        }
    }

    #[test]
    fn d_star_memory_below_one_and_h1_above_demb() {
        for n in [100u64, 1024, 100_000] {
            let d = d_star_memory(n);
            assert!(d > 0.0 && d < 1.0, "n={n} d={d}");
            // Check it satisfies N = 2d³ + (N+1)d².
            let lhs = n as f64;
            let rhs = 2.0 * d.powi(3) + (n as f64 + 1.0) * d * d;
            assert!((lhs - rhs).abs() / lhs < 1e-9);
            assert!(h1_hat(256, n) > 256.0);
        }
    }

    #[test]
    fn table2_monotone_in_d() {
        let rows = table2();
        assert_eq!(rows.len(), 5);
        for w in rows.windows(2) {
            assert!(w[0].1 < w[1].1);
            assert!(w[0].2 < w[1].2);
        }
        // All rows: N1 < N0.
        for (_, n0v, n1v) in rows {
            assert!(n1v < n0v);
        }
    }

    #[test]
    fn fig2_observation_memory_crossover_before_speed() {
        // For d=64: paper abstract says memory-efficient from ~800 tokens
        // and faster from ~1700 at the full-transformer level; at the
        // module level Eq. 7/9 give N0(64)=4161, N1(64)=2174.
        assert_eq!(n0(64).round() as u64, 4161);
        assert_eq!(n1(64).round() as u64, 2174);
        let _ = memory::entries_efficient(2174, 64); // cross-module sanity
    }
}

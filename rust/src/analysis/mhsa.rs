//! Multi-head scaling laws (paper Section 4.3).
//!
//! With embedding width `d_emb` split across `h` heads (per-head
//! dimension `d = d_emb / h`), the cost of multi-head self-attention is
//! `h ×` the single-head cost. The paper shows that for
//! efficient-TaylorShift both FLOPs and memory *decrease* as h grows
//! throughout the admissible range `h ∈ {1, …, d_emb}` — the basis of
//! the Table 5 ablation.

use crate::analysis::{flops, memory};

/// ops_triv[MHSA] = 4N²d_emb + 6hN² (strictly increasing in h).
pub fn ops_direct_mhsa(n: u64, d_emb: u64, h: u64) -> u64 {
    assert!(h > 0 && d_emb % h == 0, "h must divide d_emb");
    h * flops::ops_direct(n, d_emb / h)
}

/// ops_eff[MHSA] = N(4 d_emb³/h² + 10 d_emb²/h + 9 d_emb + 4h).
pub fn ops_efficient_mhsa(n: u64, d_emb: u64, h: u64) -> u64 {
    assert!(h > 0 && d_emb % h == 0, "h must divide d_emb");
    h * flops::ops_efficient(n, d_emb / h)
}

/// entries_triv[MHSA] = d_emb·N + 2N²h.
pub fn entries_direct_mhsa(n: u64, d_emb: u64, h: u64) -> u64 {
    assert!(h > 0 && d_emb % h == 0, "h must divide d_emb");
    h * memory::entries_direct(n, d_emb / h)
}

/// entries_eff[MHSA] = h(d³ + (N+1)d² + 3Nd + N) with d = d_emb/h.
///
/// NOTE: the paper's Eq. 8 per-head entry count is
/// `d²(d+1) + 2dN + (d+1)N + d²N = d³ + (N+1)d² + 3Nd + N + ...`;
/// expanding: d²·d + d² + 2dN + dN + N + d²N = d³ + d²(N+1) + 3dN + N. ✓
pub fn entries_efficient_mhsa(n: u64, d_emb: u64, h: u64) -> u64 {
    assert!(h > 0 && d_emb % h == 0, "h must divide d_emb");
    h * memory::entries_efficient(n, d_emb / h)
}

/// Divisor heads of `d_emb` in ascending order (the admissible h values).
pub fn admissible_heads(d_emb: u64) -> Vec<u64> {
    (1..=d_emb).filter(|h| d_emb % h == 0).collect()
}

/// The head count among divisors of d_emb that minimizes efficient-MHSA
/// FLOPs at a given N. By Section 4.3 this is always the largest
/// divisor (= d_emb, i.e. d = 1), since ĥ₀ > d_emb.
pub fn best_heads_for_ops(n: u64, d_emb: u64) -> u64 {
    admissible_heads(d_emb)
        .into_iter()
        .min_by_key(|&h| ops_efficient_mhsa(n, d_emb, h))
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expanded_forms_match_paper() {
        // ops_triv[MHSA] = 4N²d_emb + 6hN²
        for (n, d_emb, h) in [(128u64, 256u64, 4u64), (1024, 256, 32), (512, 192, 3)] {
            let expect = 4 * n * n * d_emb + 6 * h * n * n;
            assert_eq!(ops_direct_mhsa(n, d_emb, h), expect);
            // ops_eff[MHSA] = N(4 d_emb³/h² + 10 d_emb²/h + 9 d_emb + 4h)
            let expect_eff = n
                * (4 * d_emb.pow(3) / (h * h) + 10 * d_emb.pow(2) / h + 9 * d_emb + 4 * h);
            assert_eq!(ops_efficient_mhsa(n, d_emb, h), expect_eff);
            // entries_triv[MHSA] = d_emb N + 2N²h
            assert_eq!(entries_direct_mhsa(n, d_emb, h), d_emb * n + 2 * n * n * h);
            // entries_eff[MHSA] = h(d³ + (N+1)d² + 3Nd + N)
            let d = d_emb / h;
            let expect_mem = h * (d.pow(3) + (n + 1) * d * d + 3 * n * d + n);
            assert_eq!(entries_efficient_mhsa(n, d_emb, h), expect_mem);
        }
    }

    #[test]
    fn efficient_ops_decrease_with_heads() {
        // Section 4.3: within {1..d_emb} more heads ⇒ fewer ops.
        let (n, d_emb) = (1024u64, 256u64);
        let heads = admissible_heads(d_emb);
        for w in heads.windows(2) {
            assert!(
                ops_efficient_mhsa(n, d_emb, w[1]) < ops_efficient_mhsa(n, d_emb, w[0]),
                "h {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn efficient_memory_decreases_with_heads() {
        let (n, d_emb) = (1024u64, 256u64);
        let heads = admissible_heads(d_emb);
        for w in heads.windows(2) {
            assert!(
                entries_efficient_mhsa(n, d_emb, w[1]) < entries_efficient_mhsa(n, d_emb, w[0])
            );
        }
    }

    #[test]
    fn direct_costs_increase_with_heads() {
        let (n, d_emb) = (1024u64, 256u64);
        let heads = admissible_heads(d_emb);
        for w in heads.windows(2) {
            assert!(ops_direct_mhsa(n, d_emb, w[1]) > ops_direct_mhsa(n, d_emb, w[0]));
            assert!(entries_direct_mhsa(n, d_emb, w[1]) > entries_direct_mhsa(n, d_emb, w[0]));
        }
    }

    #[test]
    fn best_heads_is_maximal_divisor() {
        assert_eq!(best_heads_for_ops(1024, 256), 256);
        assert_eq!(best_heads_for_ops(128, 192), 192);
    }

    #[test]
    fn admissible_heads_are_divisors() {
        let hs = admissible_heads(256);
        assert_eq!(hs, vec![1, 2, 4, 8, 16, 32, 64, 128, 256]);
    }

    #[test]
    fn table5_direction_throughput_vs_heads() {
        // Table 5 setup: d_emb=256, N=1024. Going h=4 → 64:
        // efficient ops shrink, direct ops grow — matching the measured
        // TP columns (2975 → 13480 ims/s eff, 12060 → 1235 direct).
        let n = 1024;
        assert!(ops_efficient_mhsa(n, 256, 64) < ops_efficient_mhsa(n, 256, 4) / 5);
        // Direct FLOPs rise only via the 6hN² term; the measured 10×
        // slowdown in Table 5 is memory-bound, not FLOP-bound. Entries,
        // however, grow steeply (2N²h dominates):
        assert!(ops_direct_mhsa(n, 256, 64) > ops_direct_mhsa(n, 256, 4));
        assert!(entries_direct_mhsa(n, 256, 64) > 8 * entries_direct_mhsa(n, 256, 4));
    }
}

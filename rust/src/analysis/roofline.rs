//! TPU roofline / VMEM estimator for the Pallas kernels
//! (DESIGN.md §Hardware-Adaptation, EXPERIMENTS.md §Perf L1).
//!
//! Pallas runs under `interpret=True` on this CPU-only environment, so
//! real-TPU efficiency cannot be *measured*; it is *estimated* from the
//! kernel's BlockSpec: VMEM residency of all live blocks, the MXU-eligible
//! FLOP fraction (matmul FLOPs / total FLOPs), tile alignment with the
//! 128×128 systolic array, and the HBM↔VMEM traffic the block schedule
//! implies. These are the numbers DESIGN.md §Perf reports.

use crate::analysis::flops;

/// TPU v4-like core budget (per-core values; conservative defaults).
#[derive(Clone, Copy, Debug)]
pub struct TpuSpec {
    /// VMEM bytes per core.
    pub vmem_bytes: u64,
    /// Peak MXU throughput, FLOP/s (bf16 with f32 accumulation).
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// MXU tile edge (lane dimension).
    pub mxu_tile: u64,
}

impl Default for TpuSpec {
    fn default() -> Self {
        Self {
            vmem_bytes: 16 << 20,   // 16 MiB
            peak_flops: 137.5e12,   // ~ v4 core nominal bf16
            hbm_bw: 600e9,          // hbm per core share
            mxu_tile: 128,
        }
    }
}

/// Static description of one Pallas kernel block schedule, mirrored from
/// the BlockSpecs in `python/compile/kernels/*.py`.
#[derive(Clone, Debug)]
pub struct KernelSchedule {
    pub name: String,
    /// Per-grid-step VMEM-resident buffers: (label, elements).
    pub blocks: Vec<(String, u64)>,
    /// Total matmul (MXU-eligible) FLOPs for the whole kernel.
    pub matmul_flops: u64,
    /// Total vector-unit (VPU) FLOPs.
    pub vector_flops: u64,
    /// Total HBM bytes moved in + out across the grid.
    pub hbm_bytes: u64,
    /// Bytes per element (4 = f32; 2 = bf16 inputs).
    pub bytes_per_elem: u64,
}

/// Roofline estimate for a schedule on a given TPU spec.
#[derive(Clone, Debug)]
pub struct Estimate {
    pub vmem_bytes: u64,
    pub fits_vmem: bool,
    /// Matmul share of total FLOPs (MXU utilization ceiling).
    pub mxu_fraction: f64,
    /// FLOPs per HBM byte.
    pub arithmetic_intensity: f64,
    /// Compute-bound if intensity exceeds the machine balance point.
    pub compute_bound: bool,
    /// Estimated runtime = max(compute time, memory time), seconds.
    pub runtime_s: f64,
    /// Fraction of peak FLOP/s achieved under the roofline model.
    pub efficiency: f64,
}

impl KernelSchedule {
    pub fn total_flops(&self) -> u64 {
        self.matmul_flops + self.vector_flops
    }

    pub fn vmem_footprint(&self) -> u64 {
        self.blocks.iter().map(|(_, e)| e).sum::<u64>() * self.bytes_per_elem
    }

    pub fn estimate(&self, spec: &TpuSpec) -> Estimate {
        let vmem = self.vmem_footprint();
        let total = self.total_flops() as f64;
        let mxu_fraction = if self.total_flops() == 0 {
            0.0
        } else {
            self.matmul_flops as f64 / total
        };
        let intensity = if self.hbm_bytes == 0 {
            f64::INFINITY
        } else {
            total / self.hbm_bytes as f64
        };
        let balance = spec.peak_flops / spec.hbm_bw;
        // VPU flops run far below MXU peak; model VPU at peak/8.
        let compute_time = self.matmul_flops as f64 / spec.peak_flops
            + self.vector_flops as f64 / (spec.peak_flops / 8.0);
        let memory_time = self.hbm_bytes as f64 / spec.hbm_bw;
        let runtime = compute_time.max(memory_time);
        Estimate {
            vmem_bytes: vmem,
            fits_vmem: vmem <= spec.vmem_bytes,
            mxu_fraction,
            arithmetic_intensity: intensity,
            compute_bound: intensity > balance,
            runtime_s: runtime,
            efficiency: if runtime > 0.0 {
                (total / spec.peak_flops) / runtime
            } else {
                0.0
            },
        }
    }
}

/// Build the schedule for the efficient-TaylorShift Pallas kernel as
/// implemented in `tsa_efficient.py`: grid over N-blocks of size `bn`;
/// VMEM holds one block each of Q, K, V(+1), the Q^⊠2/K^⊠2 expansion of
/// the current block, and the (d²+d+1)×(d+1) accumulator A_full.
pub fn efficient_schedule(n: u64, d: u64, bn: u64, bytes_per_elem: u64) -> KernelSchedule {
    let d2 = d * d;
    let blocks = vec![
        ("q_block".to_string(), bn * d),
        ("k_block".to_string(), bn * d),
        ("v_block".to_string(), bn * (d + 1)),
        ("kbox_block".to_string(), bn * d2),
        ("qbox_block".to_string(), bn * d2),
        ("a_full_acc".to_string(), (d2 + d + 1) * (d + 1)),
        ("y_block".to_string(), bn * (d + 1)),
    ];
    let eff = flops::EfficientBreakdown::new(n, d);
    // Matmul-eligible: the two d²-sized contractions + the linear term.
    let matmul = eff.squared_term - 2 * n * d2 /* tensor expansions are VPU */ + eff.linear_term;
    let vector = eff.total() - matmul;
    // HBM traffic: read Q,K,V once, write Y once (streaming schedule).
    let hbm = (3 * n * d + n * (d + 1) + n * d) * bytes_per_elem;
    KernelSchedule {
        name: format!("tsa_efficient n={n} d={d} bn={bn}"),
        blocks,
        matmul_flops: matmul,
        vector_flops: vector,
        hbm_bytes: hbm,
        bytes_per_elem,
    }
}

/// Schedule for direct-TaylorShift: grid over (row-block, col-block)
/// tiles of the N×N score matrix.
pub fn direct_schedule(n: u64, d: u64, bn: u64, bytes_per_elem: u64) -> KernelSchedule {
    let blocks = vec![
        ("q_block".to_string(), bn * d),
        ("k_block".to_string(), bn * d),
        ("v_block".to_string(), bn * d),
        ("scores_tile".to_string(), bn * bn),
        ("acc".to_string(), bn * (d + 1)),
    ];
    let total = flops::ops_direct(n, d);
    let matmul = 4 * n * n * d; // QKᵀ and ·V
    // HBM: Q read once per row-block; K,V re-read once per row-block pass.
    let passes = n.div_ceil(bn);
    let hbm = (n * d + passes * 2 * n * d + n * d) * bytes_per_elem;
    KernelSchedule {
        name: format!("tsa_direct n={n} d={d} bn={bn}"),
        blocks,
        matmul_flops: matmul,
        vector_flops: total - matmul,
        hbm_bytes: hbm,
        bytes_per_elem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficient_fits_vmem_for_paper_configs() {
        let spec = TpuSpec::default();
        // d=64, block 256 rows, f32: the ⊠-expanded blocks are bn·d²
        // elements, so bn must stay ≤ ~384 at d=64 to fit 16 MiB VMEM.
        let s = efficient_schedule(16_384, 64, 256, 4);
        let e = s.estimate(&spec);
        assert!(e.fits_vmem, "vmem={} bytes", e.vmem_bytes);
    }

    #[test]
    fn oversized_block_overflows_vmem() {
        let spec = TpuSpec::default();
        // d=128 ⇒ d²=16384; bn=2048 blocks of d² elements are 128 MiB.
        let s = efficient_schedule(16_384, 128, 2048, 4);
        assert!(!s.estimate(&spec).fits_vmem);
    }

    #[test]
    fn mxu_fraction_high_for_both() {
        let e = efficient_schedule(8192, 64, 256, 4).estimate(&TpuSpec::default());
        assert!(e.mxu_fraction > 0.9, "eff mxu={}", e.mxu_fraction);
        let d = direct_schedule(8192, 64, 256, 4).estimate(&TpuSpec::default());
        assert!(d.mxu_fraction > 0.9, "dir mxu={}", d.mxu_fraction);
    }

    #[test]
    fn efficient_is_compute_bound_at_long_n() {
        // The streaming schedule reads QKV once ⇒ intensity ~ O(d²),
        // far beyond machine balance for d ≥ 32.
        let e = efficient_schedule(100_000, 64, 512, 4).estimate(&TpuSpec::default());
        assert!(e.compute_bound);
        assert!(e.efficiency > 0.5, "eff={}", e.efficiency);
    }

    #[test]
    fn runtime_crossover_matches_analysis_direction() {
        let spec = TpuSpec::default();
        let d = 64;
        // Far above N0: efficient should be estimated faster.
        let t_eff = efficient_schedule(32_768, d, 512, 4).estimate(&spec).runtime_s;
        let t_dir = direct_schedule(32_768, d, 512, 4).estimate(&spec).runtime_s;
        assert!(t_eff < t_dir);
        // Far below N0: direct faster.
        let t_eff = efficient_schedule(256, d, 128, 4).estimate(&spec).runtime_s;
        let t_dir = direct_schedule(256, d, 128, 4).estimate(&spec).runtime_s;
        assert!(t_dir < t_eff);
    }

    #[test]
    fn flop_totals_consistent_with_analysis() {
        let (n, d) = (4096u64, 32u64);
        let s = efficient_schedule(n, d, 256, 4);
        assert_eq!(s.total_flops(), flops::ops_efficient(n, d));
        let s = direct_schedule(n, d, 256, 4);
        assert_eq!(s.total_flops(), flops::ops_direct(n, d));
    }
}

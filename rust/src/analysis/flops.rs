//! Floating-point-operation counts for the attention variants
//! (paper Section 4.1).

/// FLOPs of direct-TaylorShift for one head (Eq. 5):
/// `4N²d + 6N²`, decomposed as
/// `2N²d` (QKᵀ) + `4N²` (elementwise ½x²+x+1) + `2N²` (normalize) +
/// `2N²d` (multiply by V).
pub fn ops_direct(n: u64, d: u64) -> u64 {
    4 * n * n * d + 6 * n * n
}

/// FLOPs of efficient-TaylorShift for one head (Eq. 6):
/// `N(4d³ + 10d² + 9d + 4)`.
pub fn ops_efficient(n: u64, d: u64) -> u64 {
    n * (4 * d * d * d + 10 * d * d + 9 * d + 4)
}

/// Per-token decode FLOPs on the KV-cache (direct) path at prefix
/// length `n`: score every cached key (`2d+3` each: dot, Taylor poly),
/// accumulate the weighted values (`2d` each), plus the query
/// normalization and output rescale (`~3d`). Linear in `n`.
pub fn ops_decode_kv(n: u64, d: u64) -> u64 {
    n * (4 * d + 3) + 3 * d
}

/// Per-token decode FLOPs on the recurrent path, independent of the
/// prefix: a rank-1 moment update plus a full moment contraction, each
/// `2d²(d+1)` for M₂ with lower-order M₁/M₀ terms — `4(d+1)(d²+d+1)`
/// total plus `~6d` for normalizations.
pub fn ops_decode_recurrent(d: u64) -> u64 {
    4 * (d + 1) * (d * d + d + 1) + 6 * d
}

/// FLOPs of standard softmax attention. The paper notes (§4.1, Fig. 2)
/// that softmax attention is "slightly higher" than direct-TaylorShift:
/// the only difference is evaluating `exp` instead of `½x²+x+1` on the
/// N² matrix. We charge exp at `EXP_FLOPS` flops/element (a common
/// convention for transcendental cost on vector units).
pub const EXP_FLOPS: u64 = 10;

pub fn ops_softmax(n: u64, d: u64) -> u64 {
    // 2N²d (QKᵀ) + EXP_FLOPS·N² (exp) + 2N² (normalize) + 2N²d (·V)
    4 * n * n * d + (EXP_FLOPS + 2) * n * n
}

/// Breakdown of Eq. 6 by term — used by the §Perf analysis and to unit
/// test the aggregate against a from-parts sum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EfficientBreakdown {
    /// ops[Y_squ] = 4Nd²(d+1) + 2Nd² (tensor op on K, two matmuls, tensor op on Q)
    pub squared_term: u64,
    /// ops[QKᵀV] computed right-to-left = 4Nd(d+1)
    pub linear_term: u64,
    /// Σ_col V = N(d+1)
    pub constant_term: u64,
    /// scalar sums/multiplications = 3N(d+1)
    pub combine: u64,
    /// final normalization (Hadamard division) = Nd
    pub normalize: u64,
}

impl EfficientBreakdown {
    pub fn new(n: u64, d: u64) -> Self {
        Self {
            squared_term: 4 * n * d * d * (d + 1) + 2 * n * d * d,
            linear_term: 4 * n * d * (d + 1),
            constant_term: n * (d + 1),
            combine: 3 * n * (d + 1),
            normalize: n * d,
        }
    }

    pub fn total(&self) -> u64 {
        self.squared_term + self.linear_term + self.constant_term + self.combine + self.normalize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficient_breakdown_matches_eq6() {
        for n in [1u64, 7, 128, 1024, 100_000] {
            for d in [1u64, 8, 16, 32, 64, 128] {
                assert_eq!(
                    EfficientBreakdown::new(n, d).total(),
                    ops_efficient(n, d),
                    "n={n} d={d}"
                );
            }
        }
    }

    #[test]
    fn direct_decomposition_matches_eq5() {
        // 2N²d + 4N² + 2N² + 2N²d
        for n in [1u64, 16, 512] {
            for d in [8u64, 64] {
                let parts = 2 * n * n * d + 4 * n * n + 2 * n * n + 2 * n * n * d;
                assert_eq!(parts, ops_direct(n, d));
            }
        }
    }

    #[test]
    fn softmax_slightly_above_direct() {
        for n in [64u64, 1024] {
            for d in [16u64, 64] {
                assert!(ops_softmax(n, d) > ops_direct(n, d));
                // but within a few percent for realistic d
                let ratio = ops_softmax(n, d) as f64 / ops_direct(n, d) as f64;
                assert!(ratio < 1.15, "ratio={ratio}");
            }
        }
    }

    #[test]
    fn efficient_is_linear_in_n() {
        let d = 32;
        let base = ops_efficient(1000, d);
        assert_eq!(ops_efficient(2000, d), 2 * base);
        assert_eq!(ops_efficient(10_000, d), 10 * base);
    }

    #[test]
    fn direct_is_quadratic_in_n() {
        let d = 32;
        let base = ops_direct(1000, d);
        assert_eq!(ops_direct(2000, d), 4 * base);
    }

    #[test]
    fn decode_costs_mirror_the_crossover() {
        let d = 16u64;
        // Recurrent cost is a constant; KV cost grows linearly, so the
        // two cross at some prefix length — the decode-time analogue of
        // the N0 speed crossover.
        let flat = ops_decode_recurrent(d);
        assert!(ops_decode_kv(16, d) < flat, "short prefixes favor KV");
        let mut crossed = false;
        for n in 1..100_000u64 {
            if ops_decode_kv(n, d) > flat {
                crossed = true;
                break;
            }
        }
        assert!(crossed, "KV decode cost never crossed the recurrent cost");
        // Linearity in n.
        let a = ops_decode_kv(1000, d) - 3 * d;
        let b = ops_decode_kv(2000, d) - 3 * d;
        assert_eq!(b, 2 * a);
    }

    #[test]
    fn paper_example_magnitudes() {
        // At d=64, N=16k the efficient variant must be well below direct.
        assert!(ops_efficient(16_384, 64) < ops_direct(16_384, 64));
        // At d=64, N=1000 (< N0≈4160) direct is cheaper.
        assert!(ops_direct(1_000, 64) < ops_efficient(1_000, 64));
    }
}

//! Peak simultaneous tensor-entry counts (paper Section 4.2).
//!
//! The paper accounts memory as the number of matrix entries alive at the
//! peak of each implementation, excluding parameters. We reproduce both
//! expressions and provide MiB conversion at a chosen element width
//! (paper Table 5 reports "MiB@16", i.e. fp16).

/// Peak entries of direct-TaylorShift:
/// `dN` (V) + `2N²` (QKᵀ and the elementwise result).
pub fn entries_direct(n: u64, d: u64) -> u64 {
    d * n + 2 * n * n
}

/// Peak entries of efficient-TaylorShift (Eq. 8):
/// `d²(d+1)` (A_mod) + `2dN` (Q, K) + `(d+1)N` (V‖1) + `d²N` (K^⊠2).
pub fn entries_efficient(n: u64, d: u64) -> u64 {
    d * d * (d + 1) + 2 * d * n + (d + 1) * n + d * d * n
}

/// Peak entries of softmax attention — identical shape analysis to
/// direct-TaylorShift (score matrix + result + V); exp is in-place, so
/// only one N×N result buffer is needed alongside the scores.
pub fn entries_softmax(n: u64, d: u64) -> u64 {
    entries_direct(n, d)
}

/// Entries held per head by a streaming KV cache at prefix length `n`:
/// `dN` normalized keys + `dN` raw values (decode-time direct branch).
pub fn entries_decode_kv(n: u64, d: u64) -> u64 {
    2 * n * d
}

/// Entries held per head by the recurrent decode state, independent of
/// the prefix length: `(d+1)` (M₀) + `d(d+1)` (M₁) + `d²(d+1)` (M₂).
pub fn entries_decode_recurrent(d: u64) -> u64 {
    (d + 1) * (1 + d + d * d)
}

/// Convert an entry count to bytes at the given element width.
pub fn bytes(entries: u64, bytes_per_elem: u64) -> u64 {
    entries * bytes_per_elem
}

/// Convert an entry count to MiB at the given element width.
pub fn mib(entries: u64, bytes_per_elem: u64) -> f64 {
    bytes(entries, bytes_per_elem) as f64 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::transitions;

    #[test]
    fn eq8_decomposition() {
        let (n, d) = (1000u64, 16u64);
        let a_mod = d * d * (d + 1);
        let qk = 2 * d * n;
        let v = (d + 1) * n;
        let kbox = d * d * n;
        assert_eq!(entries_efficient(n, d), a_mod + qk + v + kbox);
    }

    #[test]
    fn efficient_wins_beyond_n1() {
        for d in [8u64, 16, 32, 64, 128] {
            let n1 = transitions::n1(d);
            let above = (n1.ceil() as u64) + 1;
            let below = (n1.floor() as u64).saturating_sub(1).max(1);
            assert!(
                entries_efficient(above, d) < entries_direct(above, d),
                "d={d} above={above}"
            );
            assert!(
                entries_efficient(below, d) >= entries_direct(below, d),
                "d={d} below={below}"
            );
        }
    }

    #[test]
    fn decode_state_crossover() {
        // The recurrent state is length-free; the KV cache is linear in
        // N, so past some prefix the recurrent state is strictly
        // smaller even accounting for its f64 entries.
        for d in [4u64, 16, 64] {
            let recurrent = bytes(entries_decode_recurrent(d), 8);
            let mut crossed = false;
            for n in 1..=8192u64 {
                if bytes(entries_decode_kv(n, d), 4) > recurrent {
                    crossed = true;
                    break;
                }
            }
            assert!(crossed, "d={d}: KV never exceeded recurrent state");
        }
        assert_eq!(entries_decode_kv(10, 16), 320);
        assert_eq!(entries_decode_recurrent(16), 17 * (1 + 16 + 256));
    }

    #[test]
    fn mib_conversion() {
        // 2^20 entries at 1 byte = 1 MiB
        assert!((mib(1 << 20, 1) - 1.0).abs() < 1e-12);
        assert!((mib(1 << 20, 2) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn direct_memory_quadratic() {
        let d = 64;
        let e1 = entries_direct(1000, d);
        let e2 = entries_direct(2000, d);
        // quadratic term dominates
        assert!(e2 > 3 * e1);
        assert!(e2 < 4 * e1 + 4 * d * 1000);
    }

    #[test]
    fn efficient_memory_linear() {
        let d = 64;
        let fixed = d * d * (d + 1);
        let e1 = entries_efficient(1000, d) - fixed;
        let e2 = entries_efficient(2000, d) - fixed;
        assert_eq!(e2, 2 * e1);
    }

    #[test]
    fn paper_fig3_claim_half_memory_at_1500() {
        // Paper §5.2: at 1500 tokens the efficient transformer needs
        // ~half the memory, at 2000 only 35%. Attention-level entry
        // counts at d=32 (Fig. 3 setup) should show the same direction.
        let d = 32;
        let r1500 = entries_efficient(1500, d) as f64 / entries_direct(1500, d) as f64;
        let r2000 = entries_efficient(2000, d) as f64 / entries_direct(2000, d) as f64;
        assert!(r1500 < 0.80, "r1500={r1500}");
        assert!(r2000 < r1500);
    }
}

//! # TaylorShift
//!
//! A full-stack reproduction of *TaylorShift: Shifting the Complexity of
//! Self-Attention from Squared to Linear (and Back) using Taylor-Softmax*
//! (Nauen, Palacio, Dengel, 2024).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! - **L1** — Pallas kernels (`python/compile/kernels/`) implementing
//!   direct- and efficient-TaylorShift plus a softmax baseline, verified
//!   against a pure-jnp oracle.
//! - **L2** — a JAX transformer encoder (`python/compile/model.py`) whose
//!   forward/backward graphs are AOT-lowered to HLO text once at build
//!   time (`make artifacts`).
//! - **L3** — this crate: a PJRT runtime that loads those artifacts, an
//!   `XlaBuilder`-based attention emitter for runtime shape
//!   specialization, a serving coordinator (router → dynamic batcher →
//!   engine) whose *variant selector* implements the paper's "(and
//!   Back)": pick direct `O(N²d)` vs efficient `O(Nd³)` per sequence
//!   length from the analytical/calibrated crossover points, a training
//!   driver, the paper's analytical cost models (Eqs. 5–12), and all data
//!   substrates (ListOps generator/evaluator, synthetic pixel & byte-text
//!   tasks).
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use taylorshift::attention::{self, AttentionVariant};
//! use taylorshift::tensor::Tensor;
//!
//! let (n, d) = (128, 16);
//! let q = Tensor::randn(&[n, d], 1);
//! let k = Tensor::randn(&[n, d], 2);
//! let v = Tensor::randn(&[n, d], 3);
//! // Both implementations compute the same function:
//! let y_dir = attention::direct::taylor_direct(&q, &k, &v, 1.0, true);
//! let y_eff = attention::efficient::taylor_efficient(&q, &k, &v, 1.0);
//! assert!(y_dir.allclose(&y_eff, 1e-4, 1e-4));
//! // The selector picks the cheaper one for a given (N, d):
//! let variant = attention::selector::Selector::analytical().select(n, d);
//! assert_eq!(variant, AttentionVariant::Direct); // N < N0(16)
//! ```

pub mod analysis;
pub mod attention;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;

pub use attention::AttentionVariant;
pub use tensor::Tensor;

//! The "(and Back)" of the paper title, as a scheduling policy.
//!
//! Direct- and efficient-TaylorShift compute the same function, so a
//! serving system can pick whichever is cheaper for each sequence
//! length. The selector encodes three policies:
//!
//! * **analytical** — switch at the FLOP-equality point N₀(d) (Eq. 7);
//! * **empirical rule** — the paper measures N̂₀ − N₀ ≈ 18·d on an A100
//!   (§5.1), so switch at N₀(d) + 18d;
//! * **calibrated** — fit the crossover from measured (N, time) samples
//!   of both variants on *this* machine (what `examples/crossover_sweep`
//!   produces and the coordinator consumes).
//!
//! Memory-constrained mode switches at N₁(d) instead (Eq. 9), since the
//! memory crossover comes much earlier than the speed crossover.

use crate::analysis::transitions;
use crate::attention::AttentionVariant;
use crate::util::stats;

/// What the selector optimizes for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize time: switch at (a possibly calibrated) N₀.
    Speed,
    /// Minimize peak memory: switch at N₁.
    Memory,
}

/// Crossover source.
#[derive(Clone, Debug)]
enum Policy {
    Analytical,
    EmpiricalRule,
    /// Explicit crossover sequence length per head dimension.
    Calibrated(Vec<(usize, f64)>),
}

/// Chooses [`AttentionVariant::Direct`] below the crossover and
/// [`AttentionVariant::Efficient`] above it.
#[derive(Clone, Debug)]
pub struct Selector {
    policy: Policy,
    objective: Objective,
}

impl Selector {
    /// Hardware-agnostic: crossover at the Table 2 values.
    pub fn analytical() -> Self {
        Self {
            policy: Policy::Analytical,
            objective: Objective::Speed,
        }
    }

    /// The paper's A100 observation N̂₀ ≈ N₀ + 18d.
    pub fn empirical_rule() -> Self {
        Self {
            policy: Policy::EmpiricalRule,
            objective: Objective::Speed,
        }
    }

    /// From measured crossovers `(d, n_cross)` (e.g. produced by
    /// `examples/crossover_sweep`). Lookup interpolates/extrapolates in d.
    pub fn calibrated(mut points: Vec<(usize, f64)>) -> Self {
        assert!(!points.is_empty(), "need at least one calibration point");
        points.sort_by_key(|p| p.0);
        Self {
            policy: Policy::Calibrated(points),
            objective: Objective::Speed,
        }
    }

    /// Load a calibration written by `examples/crossover_sweep`
    /// (`bench_out/crossover.json`): `{"points": [{"d": .., "crossover": ..}]}`.
    pub fn from_json_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let points = json
            .get("points")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("calibration missing 'points'"))?
            .iter()
            .map(|p| {
                Some((
                    p.get("d")?.as_usize()?,
                    p.get("crossover")?.as_f64()?,
                ))
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow::anyhow!("malformed calibration point"))?;
        anyhow::ensure!(!points.is_empty(), "empty calibration");
        Ok(Self::calibrated(points))
    }

    /// Switch objective to memory (uses N₁ for analytical policies).
    pub fn for_memory(mut self) -> Self {
        self.objective = Objective::Memory;
        self
    }

    /// The crossover sequence length for head dimension `d`.
    pub fn crossover(&self, d: usize) -> f64 {
        let analytical = match self.objective {
            Objective::Speed => transitions::n0(d as u64),
            Objective::Memory => transitions::n1(d as u64),
        };
        match &self.policy {
            Policy::Analytical => analytical,
            Policy::EmpiricalRule => match self.objective {
                // §5.1: speed crossover shifts by ≈18d on real hardware;
                // the memory crossover matches theory within 0.6%.
                Objective::Speed => analytical + 18.0 * d as f64,
                Objective::Memory => analytical,
            },
            Policy::Calibrated(points) => interpolate(points, d),
        }
    }

    /// Pick the variant for a sequence of length `n` at head dim `d`.
    pub fn select(&self, n: usize, d: usize) -> AttentionVariant {
        if (n as f64) < self.crossover(d) {
            AttentionVariant::Direct
        } else {
            AttentionVariant::Efficient
        }
    }
}

/// Piecewise-linear interpolation in d with flat extrapolation.
fn interpolate(points: &[(usize, f64)], d: usize) -> f64 {
    let df = d as f64;
    if df <= points[0].0 as f64 {
        return points[0].1;
    }
    if df >= points[points.len() - 1].0 as f64 {
        return points[points.len() - 1].1;
    }
    for w in points.windows(2) {
        let (d0, c0) = (w[0].0 as f64, w[0].1);
        let (d1, c1) = (w[1].0 as f64, w[1].1);
        if df >= d0 && df <= d1 {
            let t = (df - d0) / (d1 - d0);
            return c0 + t * (c1 - c0);
        }
    }
    unreachable!()
}

/// Calibrate a speed crossover from timing curves of both variants:
/// `ns[i]` with `t_direct[i]`, `t_efficient[i]` seconds. Returns the
/// interpolated first intersection, or `None` when the curves do not
/// cross in the sampled range (caller falls back to the analytical
/// point).
pub fn calibrate_crossover(ns: &[usize], t_direct: &[f64], t_efficient: &[f64]) -> Option<f64> {
    let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    // direct starts cheaper; crossover where it stops being cheaper.
    stats::crossover(&xs, t_direct, t_efficient)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{pair, run, Config, Gen};

    #[test]
    fn analytical_matches_table2() {
        let s = Selector::analytical();
        // d=64: N0 ≈ 4161.
        assert_eq!(s.select(4000, 64), AttentionVariant::Direct);
        assert_eq!(s.select(4300, 64), AttentionVariant::Efficient);
        // d=16: N0 = (4·4096+10·256+144+4)/70 ≈ 271.
        assert_eq!(s.select(200, 16), AttentionVariant::Direct);
        assert_eq!(s.select(300, 16), AttentionVariant::Efficient);
    }

    #[test]
    fn memory_objective_switches_earlier() {
        for d in [8usize, 16, 32, 64, 128] {
            let speed = Selector::analytical();
            let mem = Selector::analytical().for_memory();
            assert!(mem.crossover(d) < speed.crossover(d), "d={d}");
        }
    }

    #[test]
    fn empirical_rule_shifts_late() {
        for d in [16usize, 64] {
            let a = Selector::analytical();
            let e = Selector::empirical_rule();
            assert!((e.crossover(d) - a.crossover(d) - 18.0 * d as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn calibrated_interpolates() {
        let s = Selector::calibrated(vec![(16, 500.0), (64, 5000.0)]);
        assert_eq!(s.crossover(16), 500.0);
        assert_eq!(s.crossover(64), 5000.0);
        let mid = s.crossover(40);
        assert!(mid > 500.0 && mid < 5000.0);
        // flat extrapolation
        assert_eq!(s.crossover(8), 500.0);
        assert_eq!(s.crossover(128), 5000.0);
    }

    #[test]
    fn calibrate_crossover_from_synthetic_curves() {
        let ns: Vec<usize> = (1..20).map(|i| i * 100).collect();
        // direct ~ aN², efficient ~ bN with crossing at N = b/a = 1000.
        let t_dir: Vec<f64> = ns.iter().map(|&n| 1e-9 * (n * n) as f64).collect();
        let t_eff: Vec<f64> = ns.iter().map(|&n| 1e-6 * n as f64).collect();
        let cross = calibrate_crossover(&ns, &t_dir, &t_eff).unwrap();
        assert!((cross - 1000.0).abs() < 1.0, "cross={cross}");
    }

    #[test]
    fn calibration_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ts_cal_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("crossover.json");
        std::fs::write(
            &path,
            r#"{"points": [{"d": 16, "crossover": 975, "analytical_n0": 273},
                           {"d": 8, "crossover": 220}]}"#,
        )
        .unwrap();
        let s = Selector::from_json_file(&path).unwrap();
        assert_eq!(s.crossover(16), 975.0);
        assert_eq!(s.crossover(8), 220.0);
        assert_eq!(s.select(900, 16), AttentionVariant::Direct); // below calibrated
        assert_eq!(s.select(1000, 16), AttentionVariant::Efficient);
        std::fs::write(&path, r#"{"points": []}"#).unwrap();
        assert!(Selector::from_json_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_selection_monotone_in_n() {
        // If efficient is selected at n, it stays selected for all n' > n.
        run(
            Config::default().cases(256),
            pair(Gen::usize_range(1, 20_000), Gen::usize_range(1, 128)),
            |&(n, d)| {
                let s = Selector::analytical();
                match s.select(n, d) {
                    AttentionVariant::Efficient => {
                        s.select(n + 1, d) == AttentionVariant::Efficient
                            && s.select(n * 2, d) == AttentionVariant::Efficient
                    }
                    AttentionVariant::Direct => true,
                    _ => false,
                }
            },
        );
    }

    #[test]
    fn prop_crossover_increases_with_d() {
        run(
            Config::default().cases(128),
            Gen::usize_range(2, 127),
            |&d| {
                let s = Selector::analytical();
                s.crossover(d + 1) > s.crossover(d)
            },
        );
    }
}

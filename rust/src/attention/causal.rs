//! Causal (autoregressive) TaylorShift reference for the streaming
//! decode path.
//!
//! [`causal_taylor`] computes, for every row `t`, the TaylorShift
//! attention of query `t` over keys/values `0..=t` — exactly the
//! function a decode session produces token-by-token, evaluated as one
//! batch. Row `t` of the output equals the streaming output at prefix
//! length `t + 1`, so a single full-length call is a reference for
//! *every* prefix of a stream at once.
//!
//! **Lockstep invariant:** the arithmetic here deliberately replicates
//! [`crate::decode::KvCache`] (rows before the promotion point) and
//! [`crate::decode::RecurrentState`] (rows at and after it)
//! operation-for-operation — the same f64 accumulation order, the same
//! f32 rounding points (cached keys are stored as f32 after an f64
//! norm), and the same `max(1e-12)` normalization guards. That makes
//! the whole-model streaming-vs-batch parity tests exact rather than
//! merely within a numerical tolerance: per-row ops (LayerNorm, MLP,
//! projections) are shared code, and the attention rows agree because
//! this file mirrors the decode state machines. If `decode/kv.rs` or
//! `decode/recurrent.rs` changes its arithmetic, this file must change
//! with it.

use crate::tensor::Tensor;
use crate::util::numeric::guard_denom;

/// Taylor-moment accumulators mirroring `RecurrentState` (f64 state,
/// unscaled `u = [1 | v]` rows; see `decode/recurrent.rs` for the
/// derivation).
struct Moments {
    d: usize,
    len: usize,
    alpha: f64,
    m0: Vec<f64>,
    m1: Vec<f64>,
    m2: Vec<f64>,
}

impl Moments {
    fn new(d: usize) -> Self {
        let w = d + 1;
        Self {
            d,
            len: 0,
            alpha: (d as f64).powf(0.25),
            m0: vec![0.0; w],
            m1: vec![0.0; d * w],
            m2: vec![0.0; d * d * w],
        }
    }

    /// Mirror of `RecurrentState::append`.
    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        let (d, w) = (self.d, self.d + 1);
        let norm = k.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let scale = self.alpha / norm.max(1e-12);
        let kn: Vec<f64> = k.iter().map(|&x| x as f64 * scale).collect();
        let mut u = vec![0.0f64; w];
        u[0] = 1.0;
        for (c, &x) in v.iter().enumerate() {
            u[c + 1] = x as f64;
        }
        for c in 0..w {
            self.m0[c] += u[c];
        }
        for a in 0..d {
            let ka = kn[a];
            let row1 = &mut self.m1[a * w..(a + 1) * w];
            for c in 0..w {
                row1[c] += ka * u[c];
            }
            for b in 0..d {
                let kab = ka * kn[b];
                let row2 = &mut self.m2[(a * d + b) * w..(a * d + b + 1) * w];
                for c in 0..w {
                    row2[c] += kab * u[c];
                }
            }
        }
        self.len += 1;
    }

    /// Mirror of `RecurrentState::query`.
    fn query(&self, q: &[f32], tau: f64) -> Vec<f32> {
        let (d, w) = (self.d, self.d + 1);
        let norm = q.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let scale = self.alpha * tau / norm.max(1e-12);
        let qn: Vec<f64> = q.iter().map(|&x| x as f64 * scale).collect();
        let a2 = self.alpha * self.alpha;
        let a4 = a2 * a2;
        let mut y = vec![0.0f64; w];
        for (c, out) in y.iter_mut().enumerate() {
            *out = a4 * self.m0[c];
        }
        for a in 0..d {
            let qa = qn[a];
            let row1 = &self.m1[a * w..(a + 1) * w];
            for (c, out) in y.iter_mut().enumerate() {
                *out += a2 * qa * row1[c];
            }
            for b in 0..d {
                let coef = 0.5 * qa * qn[b];
                let row2 = &self.m2[(a * d + b) * w..(a * d + b + 1) * w];
                for (c, out) in y.iter_mut().enumerate() {
                    *out += coef * row2[c];
                }
            }
        }
        let denom = guard_denom(y[0]);
        let rescale = (self.len as f64 / d as f64).sqrt();
        (0..d).map(|c| (y[c + 1] / denom * rescale) as f32).collect()
    }
}

/// Causal TaylorShift attention for one head: row `t` of the output is
/// query `t` attended over keys/values `0..=t`.
///
/// `promote_at` mirrors a decode session's KV→recurrent switch:
///
/// * `None` — every row is served from the KV formulation (a session
///   that never crosses its threshold).
/// * `Some(p)` — rows with prefix length `< p` are KV; at prefix `p`
///   the cached (f32-rounded normalized key, raw value) pairs are
///   replayed into Taylor moments, and rows with prefix `≥ p` are
///   served recurrent. `Some(1)` (or `Some(0)`) is a session born on
///   the recurrent branch.
///
/// The replay happens *before* token `p-1` (0-indexed) is absorbed, so
/// the moments hold the f32-normalized keys of tokens `0..p-1` plus
/// the raw keys of every later token — the exact state a promoted
/// `DecodeSession` carries.
pub fn causal_taylor(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    tau: f32,
    promote_at: Option<usize>,
) -> Tensor {
    assert_eq!(q.shape(), k.shape(), "q/k shape mismatch");
    assert_eq!(q.shape(), v.shape(), "q/v shape mismatch");
    assert_eq!(q.rank(), 2, "causal_taylor expects [n, d]");
    let (n, d) = (q.shape()[0], q.shape()[1]);
    let tau64 = tau as f64;
    let mut out = Tensor::zeros(&[n, d]);
    // KV phase: keys stored f32-rounded after an f64 norm, exactly as
    // `KvCache::append` stores them.
    let mut keys: Vec<f32> = Vec::new();
    let mut moments: Option<Moments> = None;
    for t in 0..n {
        let new_len = t + 1;
        // Promote-then-append, as in `DecodeSession::step`: replay the
        // cached normalized keys of tokens 0..t, then absorb token t raw.
        if moments.is_none() && promote_at.is_some_and(|p| new_len >= p) {
            let mut m = Moments::new(d);
            for j in 0..t {
                m.absorb(&keys[j * d..(j + 1) * d], v.row(j));
            }
            moments = Some(m);
        }
        if let Some(m) = moments.as_mut() {
            m.absorb(k.row(t), v.row(t));
            out.row_mut(t).copy_from_slice(&m.query(q.row(t), tau64));
        } else {
            // Mirror of `KvCache::append`.
            let kr = k.row(t);
            let norm = kr.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            let scale = (1.0 / norm.max(1e-12)) as f32;
            keys.extend(kr.iter().map(|&x| x * scale));
            // Mirror of `KvCache::query` over rows 0..=t.
            let qr = q.row(t);
            let qnorm = qr.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            let qscale = tau64 / qnorm.max(1e-12);
            let qn: Vec<f64> = qr.iter().map(|&x| x as f64 * qscale).collect();
            let mut num = vec![0.0f64; d];
            let mut den = 0.0f64;
            for j in 0..new_len {
                let key = &keys[j * d..(j + 1) * d];
                let mut s = 0.0f64;
                for c in 0..d {
                    s += qn[c] * key[c] as f64;
                }
                let w = 1.0 + s + 0.5 * s * s;
                den += w;
                let val = v.row(j);
                for c in 0..d {
                    num[c] += w * val[c] as f64;
                }
            }
            let rescale = (new_len as f64 / d as f64).sqrt() / guard_denom(den);
            for (o, &x) in out.row_mut(t).iter_mut().zip(&num) {
                *o = (x * rescale) as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::DecodeSession;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::randn(&[n, d], seed),
            Tensor::randn(&[n, d], seed + 1),
            Tensor::randn(&[n, d], seed + 2),
        )
    }

    fn stream_rows(
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        tau: f32,
        crossover: Option<f64>,
        start_recurrent: bool,
    ) -> Tensor {
        let (n, d) = (q.shape()[0], q.shape()[1]);
        let mut session = DecodeSession::new(1, d, tau, start_recurrent);
        let mut out = Tensor::zeros(&[n, d]);
        for t in 0..n {
            let row = |src: &Tensor| Tensor::new(&[1, d], src.row(t).to_vec());
            let r = session.step(&row(q), &row(k), &row(v), crossover);
            out.row_mut(t).copy_from_slice(&r.output);
        }
        out
    }

    /// The whole point of this module: every row must be *bitwise*
    /// identical to what the decode state machines produce, for pure-KV,
    /// born-recurrent, and mid-stream-promoted sessions alike.
    #[test]
    fn mirrors_decode_session_exactly() {
        let (n, d, tau) = (24usize, 6usize, 1.2f32);
        let (q, k, v) = qkv(n, d, 77);
        for (promote_at, crossover, start_recurrent) in [
            (None, None, false),
            (Some(1), None, true),
            (Some(9), Some(9.0), false),
        ] {
            let batch = causal_taylor(&q, &k, &v, tau, promote_at);
            let stream = stream_rows(&q, &k, &v, tau, crossover, start_recurrent);
            assert_eq!(
                batch.data(),
                stream.data(),
                "promote_at={promote_at:?} must be bit-exact vs streaming"
            );
        }
    }

    /// Against the independent batch implementations the agreement is
    /// numerical (different summation orders), not bitwise.
    #[test]
    fn last_row_matches_batch_variants() {
        let (n, d, tau) = (32usize, 8usize, 0.9f32);
        let (q, k, v) = qkv(n, d, 31);
        let kv_rows = causal_taylor(&q, &k, &v, tau, None);
        let want_dir = crate::attention::direct::taylor_direct(&q, &k, &v, tau, true);
        let diff = Tensor::new(&[1, d], kv_rows.row(n - 1).to_vec())
            .max_abs_diff(&Tensor::new(&[1, d], want_dir.row(n - 1).to_vec()));
        assert!(diff < 1e-4, "KV row vs taylor_direct: {diff}");

        let rec_rows = causal_taylor(&q, &k, &v, tau, Some(1));
        let want_eff = crate::attention::efficient::taylor_efficient(&q, &k, &v, tau);
        let diff = Tensor::new(&[1, d], rec_rows.row(n - 1).to_vec())
            .max_abs_diff(&Tensor::new(&[1, d], want_eff.row(n - 1).to_vec()));
        assert!(diff < 1e-4, "recurrent row vs taylor_efficient: {diff}");
    }
}

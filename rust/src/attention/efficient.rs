//! Efficient-TaylorShift — paper Algorithm 1 (Sections 3.2–3.3).
//!
//! Computes the *same function* as [`super::direct`] in `O(Nd³)` time and
//! `O(Nd²)` memory by linearizing the squared Gram term through the
//! row-wise tensor product: `(QKᵀ)⊙² V = Q^⊠2 ((K^⊠2)ᵀ V)`, evaluated
//! right-to-left, with nominator and denominator carried jointly by
//! prepending a ones-column to V.

use crate::tensor::Tensor;
use crate::util::numeric::guard_denom_f32;

/// Algorithm 1: efficient-TaylorShift with normalization.
///
/// * `q, k, v` — `N×d` per-head inputs.
/// * `tau` — learnable per-head temperature (Section 3.3).
///
/// Returns the `N×d` attention output; bitwise-comparable (up to f32
/// rounding) with `taylor_direct(q, k, v, tau, true)`.
pub fn taylor_efficient(q: &Tensor, k: &Tensor, v: &Tensor, tau: f32) -> Tensor {
    let (n, d) = (q.shape()[0], q.shape()[1]);
    assert_eq!(k.shape(), &[n, d]);
    assert_eq!(v.shape(), &[n, d]);

    // Line 4: α = d^(1/4).
    let alpha = (d as f32).powf(0.25);

    // Line 5: V ← (1/N) ((√(d/N)·1_N) ∘ V) ∈ R^{N×(d+1)}.
    // The ones column carries the denominator; pre-scaling it by √(d/N)
    // realizes the output normalization √(N/d) at zero extra cost
    // (paper footnote 8).
    let denom_col = Tensor::full(&[n, 1], (d as f32 / n as f32).sqrt());
    let v_aug = denom_col.concat_cols(v).scale(1.0 / n as f32);

    // Line 6: Q ← α·τ·Q/‖Q‖ row-wise, K ← α·K/‖K‖ row-wise.
    let qn = q.normalize_rows(alpha * tau);
    let kn = k.normalize_rows(alpha);

    // Line 7: A_mod ← (K ⊠ K)ᵀ V   (d² × (d+1)).
    let kbox = kn.boxtimes(&kn);
    let a_mod = kbox.transpose().matmul(&v_aug);

    // Line 8: Ŷ ← (Q ⊠ Q) A_mod   (N × (d+1)).
    let qbox = qn.boxtimes(&qn);
    let y_sq = qbox.matmul(&a_mod);

    // Line 9: Ŷ ← ½Ŷ + α²·Q(KᵀV) + α⁴·Σᵢ V_i.
    // (The α-powers restore the Taylor coefficients after the d^¼ input
    // scaling — footnote 7.)
    let ktv = kn.transpose().matmul(&v_aug); // d × (d+1)
    let y_lin = qn.matmul(&ktv); // N × (d+1)
    let col_sums = v_aug.col_sums(); // (d+1)
    let a2 = alpha * alpha;
    let a4 = a2 * a2;
    let mut y_hat = Tensor::zeros(&[n, d + 1]);
    for i in 0..n {
        let sq = y_sq.row(i);
        let lin = y_lin.row(i);
        let out = y_hat.row_mut(i);
        for j in 0..=d {
            out[j] = 0.5 * sq[j] + a2 * lin[j] + a4 * col_sums.data()[j];
        }
    }

    // Lines 10–11: split off denominator, Hadamard division.
    let (y_denom, y_nom) = y_hat.split_cols(1);
    let mut y = y_nom;
    for i in 0..n {
        // ≥ α⁴/N in exact arithmetic; the guard only bites on
        // degenerate (overflowed/cancelled) rows instead of emitting
        // inf/NaN in release builds.
        let denom = guard_denom_f32(y_denom.at2(i, 0));
        let row = y.row_mut(i);
        for x in row.iter_mut() {
            *x /= denom;
        }
    }
    y
}

/// Efficient-TaylorShift WITHOUT the normalization scheme — the naive
/// linearization whose intermediate values grow as Table 1 predicts
/// (`A_mod ~ (N+1)/√d`, `Y_denom ~ N(d+2)/2d`, …) and which overflows /
/// fails to converge in training (Fig. 4, Appendix B.1). Kept for the
/// ablation and the divergence demo.
pub fn taylor_efficient_unnormalized(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (n, d) = (q.shape()[0], q.shape()[1]);
    let denom_col = Tensor::ones(&[n, 1]);
    let v_aug = denom_col.concat_cols(v);
    let kbox = k.boxtimes(k);
    let a_mod = kbox.transpose().matmul(&v_aug);
    let qbox = q.boxtimes(q);
    let y_sq = qbox.matmul(&a_mod);
    let ktv = k.transpose().matmul(&v_aug);
    let y_lin = q.matmul(&ktv);
    let col_sums = v_aug.col_sums();
    let mut y_hat = Tensor::zeros(&[n, d + 1]);
    for i in 0..n {
        for j in 0..=d {
            y_hat.row_mut(i)[j] =
                0.5 * y_sq.at2(i, j) + y_lin.at2(i, j) + col_sums.data()[j];
        }
    }
    let (y_denom, y_nom) = y_hat.split_cols(1);
    let mut y = y_nom;
    for i in 0..n {
        let denom = y_denom.at2(i, 0);
        let row = y.row_mut(i);
        for x in row.iter_mut() {
            // lint: allow(unguarded-div) -- ablation of the paper's Fig. 4 divergence: the unnormalized pipeline must overflow exactly as Table 1 predicts, so no guard
            *x /= denom;
        }
    }
    y
}

/// Intermediate-expression mean sizes (row norms) for the scaling study
/// of Table 1 / Fig. 5: returns
/// `(‖A_mod‖, ‖(QKᵀ)²V‖, ‖QKᵀV‖, |Y_denom|, ‖Y‖)` means for inputs with
/// unit-sphere rows (the paper's sampling regime, *without* the
/// counteracting normalization — this is what motivates it).
pub fn intermediate_sizes(q: &Tensor, k: &Tensor, v: &Tensor) -> (f64, f64, f64, f64, f64) {
    let n = q.shape()[0];
    let denom_col = Tensor::ones(&[n, 1]);
    let v_aug = denom_col.concat_cols(v);
    let kbox = k.boxtimes(k);
    let a_mod = kbox.transpose().matmul(&v_aug);
    let qbox = q.boxtimes(q);
    let y_sq = qbox.matmul(&a_mod); // (QKᵀ)²·(1∘V)
    let ktv = k.transpose().matmul(&v_aug);
    let y_lin = q.matmul(&ktv); // QKᵀ·(1∘V)
    let col_sums = v_aug.col_sums();
    let mut y_hat = Tensor::zeros(&[n, v_aug.shape()[1]]);
    for i in 0..n {
        for j in 0..v_aug.shape()[1] {
            y_hat.row_mut(i)[j] =
                0.5 * y_sq.at2(i, j) + y_lin.at2(i, j) + col_sums.data()[j];
        }
    }
    let (y_denom, y_nom) = y_hat.split_cols(1);
    let mut y = y_nom.clone();
    for i in 0..n {
        let denom = y_denom.at2(i, 0);
        for x in y.row_mut(i).iter_mut() {
            // lint: allow(unguarded-div) -- Table 1 scaling study measures the raw intermediate growth; guarding would mask the blow-up it exists to demonstrate
            *x /= denom;
        }
    }
    // Strip the denominator column from the squared/linear diagnostics so
    // sizes match the paper's expressions over V alone. Matrix-valued
    // intermediates use the Frobenius norm (the measure under which the
    // paper's (N+1)/√d and N/d laws hold — the un-scaled denominator
    // column dominates A_mod); per-row results use mean row norms.
    let (_, y_sq_v) = y_sq.split_cols(1);
    let (_, y_lin_v) = y_lin.split_cols(1);
    (
        a_mod.frobenius(),
        y_sq_v.frobenius(),
        y_lin_v.frobenius(),
        y_denom.mean_row_norm(),
        y.mean_row_norm(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::direct;

    #[test]
    fn efficient_equals_direct_normalized() {
        for (n, d, seed) in [(8usize, 4usize, 1u64), (33, 8, 2), (64, 16, 3), (100, 3, 4)] {
            let q = Tensor::randn(&[n, d], seed);
            let k = Tensor::randn(&[n, d], seed + 100);
            let v = Tensor::randn(&[n, d], seed + 200);
            let tau = 1.0 + seed as f32 * 0.25;
            let eff = taylor_efficient(&q, &k, &v, tau);
            let dir = direct::taylor_direct(&q, &k, &v, tau, true);
            assert!(
                eff.allclose(&dir, 1e-3, 1e-4),
                "n={n} d={d} diff={}",
                eff.max_abs_diff(&dir)
            );
        }
    }

    #[test]
    fn unnormalized_matches_plain_direct() {
        // Without normalization the two formulations are also identical
        // mathematically (Section 3.2 derivation).
        let (n, d) = (20, 6);
        let q = Tensor::randn(&[n, d], 7).scale(0.4);
        let k = Tensor::randn(&[n, d], 8).scale(0.4);
        let v = Tensor::randn(&[n, d], 9);
        let eff = taylor_efficient_unnormalized(&q, &k, &v);
        let dir = direct::taylor_direct_plain(&q, &k, &v);
        assert!(
            eff.allclose(&dir, 1e-3, 1e-4),
            "diff={}",
            eff.max_abs_diff(&dir)
        );
    }

    #[test]
    fn output_mean_size_near_one() {
        // Section 3.3: normalization keeps E‖Y_row‖ ≈ 1 independent of N, d.
        for (n, d) in [(256usize, 8usize), (1024, 16), (512, 32)] {
            let q = Tensor::randn(&[n, d], 11);
            let k = Tensor::randn(&[n, d], 12);
            let v = Tensor::rand_unit_rows(n, d, 13);
            let y = taylor_efficient(&q, &k, &v, 1.0);
            let size = y.mean_row_norm();
            // "Consistent" means O(1) across the N sweep — near-uniform
            // attention over unit-sphere values lands around 1/√d, far
            // from the unnormalized pipeline's N-dependent growth.
            assert!(
                (0.05..5.0).contains(&size),
                "n={n} d={d} mean row norm={size}"
            );
        }
    }

    #[test]
    fn unnormalized_intermediates_grow_linearly_with_n() {
        // Table 1: ‖A_mod‖ ≈ (N+1)/√d and |Y_denom| ≈ N(d+2)/(2d).
        let d = 8;
        let sizes: Vec<(f64, f64)> = [128usize, 256, 512]
            .iter()
            .map(|&n| {
                let q = Tensor::rand_unit_rows(n, d, 21);
                let k = Tensor::rand_unit_rows(n, d, 22);
                let v = Tensor::rand_unit_rows(n, d, 23);
                let (a_mod, _, _, y_denom, _) = intermediate_sizes(&q, &k, &v);
                (a_mod, y_denom)
            })
            .collect();
        // Doubling N should roughly double both (±40% tolerance — these
        // are stochastic fits; the precise check lives in the python
        // scaling study with 16k samples).
        for w in sizes.windows(2) {
            let ratio_a = w[1].0 / w[0].0;
            let ratio_d = w[1].1 / w[0].1;
            assert!((1.5..2.6).contains(&ratio_a), "A_mod ratio={ratio_a}");
            assert!((1.5..2.6).contains(&ratio_d), "Y_denom ratio={ratio_d}");
        }
    }

    #[test]
    fn table1_growth_directions() {
        // Directional reproduction of Table 1 (the exact prefactors are
        // empirical fits under the paper's norm convention; the python
        // scaling study in `compile/scaling_study.py` fits the full
        // curves). Here: A_mod and Y_denom grow with N while the final
        // normalized Y *shrinks* with N (~√(d/N)) — exactly the
        // imbalance the Section 3.3 normalization corrects.
        let d = 16usize;
        let measure = |n: usize| {
            let q = Tensor::rand_unit_rows(n, d, 31);
            let k = Tensor::rand_unit_rows(n, d, 32);
            let v = Tensor::rand_unit_rows(n, d, 33);
            intermediate_sizes(&q, &k, &v)
        };
        let (a1, _, _, dn1, y1) = measure(128);
        let (a2, _, _, dn2, y2) = measure(1024);
        assert!(a2 > 4.0 * a1, "A_mod should grow ~N: {a1} -> {a2}");
        assert!(dn2 > 4.0 * dn1, "Y_denom should grow ~N: {dn1} -> {dn2}");
        assert!(y2 < y1, "normalized Y should shrink with N: {y1} -> {y2}");
        // Y ≈ √(d/N) within a factor of ~4.
        let pred = (d as f64 / 1024.0).sqrt();
        assert!(y2 / pred < 4.0 && y2 / pred > 0.25, "Y {y2} vs {pred}");
    }

    #[test]
    fn linear_memory_no_nxn_allocation() {
        // Structural property: efficient path never allocates an N×N
        // tensor. We can't intercept allocations, but we can run a size
        // that would OOM-ish under N² f32 in a debug heap check… instead
        // assert the function completes quickly for N=4096, d=4 (N²=16M
        // entries would be slow in the direct path's matmul).
        let (n, d) = (4096, 4);
        let q = Tensor::randn(&[n, d], 41);
        let k = Tensor::randn(&[n, d], 42);
        let v = Tensor::randn(&[n, d], 43);
        let y = taylor_efficient(&q, &k, &v, 1.0);
        assert_eq!(y.shape(), &[n, d]);
    }
}

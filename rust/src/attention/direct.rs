//! Direct-TaylorShift (paper Section 3.1 + normalization of Section 3.3).
//!
//! Materializes the N×N Taylor-softmax attention matrix
//! `T-SM(QKᵀ) = normalize(1 + QKᵀ + ½(QKᵀ)⊙²)` and multiplies by V —
//! `O(N²d)` time, `O(N²)` memory, the fast choice for `N < N₀(d)`.

use crate::tensor::Tensor;

/// Plain direct-TaylorShift, Eq. (1): no input/output normalization
/// (the "Plain impl." row of the Table 4 ablation). `q,k,v: N×d`.
pub fn taylor_direct_plain(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let n = q.shape()[0];
    let scores = q.matmul(&k.transpose());
    let mut a = scores.map(|x| 1.0 + x + 0.5 * x * x);
    // Row-wise ℓ1 normalization (entries are ≥ 0 for even k).
    for i in 0..n {
        let row = a.row_mut(i);
        let denom: f32 = row.iter().map(|x| x.abs()).sum::<f32>().max(1e-12);
        for x in row.iter_mut() {
            *x /= denom;
        }
    }
    a.matmul(v)
}

/// Direct-TaylorShift with the paper's normalization scheme, kept
/// interchangeable with [`super::efficient::taylor_efficient`]: rows of
/// Q are ℓ2-normalized and scaled by the temperature `tau`, rows of K
/// ℓ2-normalized, and the output is scaled by `√(N/d)` so its mean size
/// is independent of N and d (Section 3.3).
///
/// With `normalized = false` this skips the q/k normalization but keeps
/// the output scaling — the "impl. + norm." vs "+output norm." stages of
/// the Table 4 ablation are exposed through [`taylor_direct_stages`].
pub fn taylor_direct(q: &Tensor, k: &Tensor, v: &Tensor, tau: f32, normalized: bool) -> Tensor {
    let (n, d) = (q.shape()[0], q.shape()[1]);
    let (qn, kn) = if normalized {
        (q.normalize_rows(tau), k.normalize_rows(1.0))
    } else {
        (q.clone(), k.clone())
    };
    let y = taylor_direct_plain(&qn, &kn, v);
    y.scale((n as f32 / d as f32).sqrt())
}

/// Ablation stages of Table 4 for the direct implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormStage {
    /// Eq. (1) as-is.
    Plain,
    /// + input normalization (q/k rows on the sphere, temperature τ).
    InputNorm,
    /// + output normalization to mean size 1 (× √(N/d)).
    InputAndOutputNorm,
}

pub fn taylor_direct_stages(
    stage: NormStage,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    tau: f32,
) -> Tensor {
    match stage {
        NormStage::Plain => taylor_direct_plain(q, k, v),
        NormStage::InputNorm => {
            taylor_direct_plain(&q.normalize_rows(tau), &k.normalize_rows(1.0), v)
        }
        NormStage::InputAndOutputNorm => taylor_direct(q, k, v, tau, true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force per-element Taylor-softmax attention to pin down the
    /// matrix form.
    fn brute_force(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        let (n, d) = (q.shape()[0], q.shape()[1]);
        let mut out = Tensor::zeros(&[n, d]);
        for i in 0..n {
            let mut weights = vec![0.0f32; n];
            for j in 0..n {
                let mut dot = 0.0;
                for c in 0..d {
                    dot += q.at2(i, c) * k.at2(j, c);
                }
                weights[j] = 1.0 + dot + 0.5 * dot * dot;
            }
            let denom: f32 = weights.iter().sum();
            for j in 0..n {
                for c in 0..d {
                    *out.at2_mut(i, c) += weights[j] / denom * v.at2(j, c);
                }
            }
        }
        out
    }

    #[test]
    fn plain_matches_brute_force() {
        let (n, d) = (17, 5);
        let q = Tensor::randn(&[n, d], 1).scale(0.3);
        let k = Tensor::randn(&[n, d], 2).scale(0.3);
        let v = Tensor::randn(&[n, d], 3);
        let a = taylor_direct_plain(&q, &k, &v);
        let b = brute_force(&q, &k, &v);
        assert!(a.allclose(&b, 1e-4, 1e-4), "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn rows_of_tsm_form_distribution() {
        // For even k the Taylor softmax is a probability distribution:
        // attention output of constant V must be that constant.
        let (n, d) = (12, 4);
        let q = Tensor::randn(&[n, d], 4);
        let k = Tensor::randn(&[n, d], 5);
        let v = Tensor::full(&[n, d], 3.5);
        let y = taylor_direct_plain(&q, &k, &v);
        for &x in y.data() {
            assert!((x - 3.5).abs() < 1e-4);
        }
    }

    #[test]
    fn normalized_output_scale_invariant_to_input_magnitude() {
        // Input normalization makes the output invariant to rescaling Q/K.
        let (n, d) = (20, 8);
        let q = Tensor::randn(&[n, d], 6);
        let k = Tensor::randn(&[n, d], 7);
        let v = Tensor::randn(&[n, d], 8);
        let y1 = taylor_direct(&q, &k, &v, 1.0, true);
        let y2 = taylor_direct(&q.scale(100.0), &k.scale(0.01), &v, 1.0, true);
        assert!(y1.allclose(&y2, 1e-3, 1e-4));
    }

    #[test]
    fn temperature_sharpens_attention() {
        // With τ → large, attention concentrates on the best-matching key;
        // output approaches that key's value row.
        let d = 4;
        let q = Tensor::new(&[1, d], vec![1.0, 0.0, 0.0, 0.0]);
        let k = Tensor::new(&[3, d], vec![1.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let v = Tensor::new(&[3, d], vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        let y_sharp = taylor_direct_plain(&q.scale(10.0), &k, &v);
        // weight for key0: 1+10+50=61; key1: 1-10+50=41; key2: 1
        let w = [61.0f32, 41.0, 1.0];
        let s: f32 = w.iter().sum();
        assert!((y_sharp.at2(0, 0) - w[0] / s).abs() < 1e-4);
        assert!((y_sharp.at2(0, 1) - w[1] / s).abs() < 1e-4);
    }

    #[test]
    fn stages_are_distinct() {
        let (n, d) = (16, 8);
        let q = Tensor::randn(&[n, d], 9).scale(2.0);
        let k = Tensor::randn(&[n, d], 10).scale(2.0);
        let v = Tensor::randn(&[n, d], 11);
        let plain = taylor_direct_stages(NormStage::Plain, &q, &k, &v, 1.0);
        let inorm = taylor_direct_stages(NormStage::InputNorm, &q, &k, &v, 1.0);
        let full = taylor_direct_stages(NormStage::InputAndOutputNorm, &q, &k, &v, 1.0);
        assert!(!plain.allclose(&inorm, 1e-3, 1e-3));
        // output norm is a pure rescale of the input-normed result
        let scale = (n as f32 / d as f32).sqrt();
        assert!(inorm.scale(scale).allclose(&full, 1e-4, 1e-4));
    }
}

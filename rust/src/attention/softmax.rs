//! Standard softmax attention (Vaswani et al.) — the paper's baseline.

use crate::tensor::Tensor;
use crate::util::numeric::guard_denom;

/// `softmax(QKᵀ/√d) V` with numerically-stable row-max subtraction.
pub fn softmax_attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (n, d) = (q.shape()[0], q.shape()[1]);
    assert_eq!(k.shape()[1], d);
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores = q.matmul(&k.transpose()).scale(scale);
    for i in 0..n {
        let row = scores.row_mut(i);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        // Same discipline as the Taylor branches: accumulate the
        // normalizer in f64 and guard it before the f32 rounding point.
        let mut sum = 0.0f64;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += f64::from(*x);
        }
        let inv = (1.0 / guard_denom(sum)) as f32;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
    scores.matmul(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one_property() {
        // Constant V passes through unchanged.
        let (n, d) = (16, 8);
        let q = Tensor::randn(&[n, d], 1);
        let k = Tensor::randn(&[n, d], 2);
        let v = Tensor::full(&[n, d], -2.0);
        let y = softmax_attention(&q, &k, &v);
        for &x in y.data() {
            assert!((x + 2.0).abs() < 1e-4);
        }
    }

    #[test]
    fn stable_under_large_scores() {
        let (n, d) = (8, 4);
        let q = Tensor::randn(&[n, d], 3).scale(100.0);
        let k = Tensor::randn(&[n, d], 4).scale(100.0);
        let v = Tensor::randn(&[n, d], 5);
        let y = softmax_attention(&q, &k, &v);
        assert!(y.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn taylor_approximates_softmax_for_small_scores() {
        // For ‖q‖,‖k‖ small the 2nd-order Taylor softmax tracks softmax
        // closely (the approximation view of [12] with its error bounds).
        let (n, d) = (24, 8);
        let q = Tensor::randn(&[n, d], 6).scale(0.1);
        let k = Tensor::randn(&[n, d], 7).scale(0.1);
        let v = Tensor::randn(&[n, d], 8);
        // Undo the 1/√d scaling by pre-scaling q.
        let q_scaled = q.scale((d as f32).sqrt());
        let soft = softmax_attention(&q_scaled, &k, &v);
        let taylor = crate::attention::direct::taylor_direct_plain(&q, &k, &v);
        assert!(
            soft.allclose(&taylor, 0.05, 0.02),
            "diff={}",
            soft.max_abs_diff(&taylor)
        );
    }

    #[test]
    fn attends_to_matching_key() {
        let d = 2;
        let q = Tensor::new(&[1, d], vec![10.0, 0.0]);
        let k = Tensor::new(&[2, d], vec![10.0, 0.0, -10.0, 0.0]);
        let v = Tensor::new(&[2, d], vec![1.0, 0.0, 0.0, 1.0]);
        let y = softmax_attention(&q, &k, &v);
        assert!(y.at2(0, 0) > 0.99);
        assert!(y.at2(0, 1) < 0.01);
    }
}

//! Pure-rust reference implementations of the paper's attention
//! mechanisms, plus the adaptive variant selector.
//!
//! These are the L3-side ground truth: integration tests compare every
//! AOT artifact and every `XlaBuilder`-emitted executable against these
//! functions, and the coordinator uses [`selector`] to realize the
//! paper's "(and Back)" — choosing direct `O(N²d)` or efficient
//! `O(Nd³)` per sequence length.

pub mod causal;
pub mod direct;
pub mod efficient;
pub mod selector;
pub mod softmax;

use crate::tensor::Tensor;

/// Which implementation of the (identical) attention function to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AttentionVariant {
    /// Materializes the N×N score matrix — `O(N²d)` time, `O(N²)` memory.
    Direct,
    /// Linearized via the ⊠ tensor trick — `O(Nd³)` time, `O(Nd²)` memory.
    Efficient,
    /// Standard softmax attention (baseline, not TaylorShift).
    Softmax,
}

impl AttentionVariant {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "direct" => Some(Self::Direct),
            "efficient" => Some(Self::Efficient),
            "softmax" => Some(Self::Softmax),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Direct => "direct",
            Self::Efficient => "efficient",
            Self::Softmax => "softmax",
        }
    }
}

impl std::fmt::Display for AttentionVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Run one attention head with the chosen variant. TaylorShift variants
/// use the paper's normalization (Algorithm 1) with temperature `tau`;
/// softmax uses `1/√d` scaling.
pub fn run_variant(
    variant: AttentionVariant,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    tau: f32,
) -> Tensor {
    match variant {
        AttentionVariant::Direct => direct::taylor_direct(q, k, v, tau, true),
        AttentionVariant::Efficient => efficient::taylor_efficient(q, k, v, tau),
        AttentionVariant::Softmax => softmax::softmax_attention(q, k, v),
    }
}

/// Multi-head self-attention over already-projected per-head tensors:
/// `q/k/v` have shape `[h, n, d]` flattened as h consecutive `n×d`
/// blocks; output is `[n, h·d]` (heads concatenated feature-wise).
pub fn mhsa(
    variant: AttentionVariant,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    h: usize,
    tau: f32,
) -> Tensor {
    assert_eq!(q.shape(), k.shape());
    assert_eq!(q.shape(), v.shape());
    assert_eq!(q.rank(), 3);
    assert_eq!(q.shape()[0], h);
    let (n, d) = (q.shape()[1], q.shape()[2]);
    let head_elems = n * d;
    let mut out = Tensor::zeros(&[n, h * d]);
    for head in 0..h {
        let slice = |t: &Tensor| {
            Tensor::new(
                &[n, d],
                t.data()[head * head_elems..(head + 1) * head_elems].to_vec(),
            )
        };
        let y = run_variant(variant, &slice(q), &slice(k), &slice(v), tau);
        for i in 0..n {
            out.row_mut(i)[head * d..(head + 1) * d].copy_from_slice(y.row(i));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        for v in [
            AttentionVariant::Direct,
            AttentionVariant::Efficient,
            AttentionVariant::Softmax,
        ] {
            assert_eq!(AttentionVariant::parse(v.name()), Some(v));
        }
        assert_eq!(AttentionVariant::parse("nope"), None);
    }

    #[test]
    fn run_variant_direct_equals_efficient() {
        let (n, d) = (24, 8);
        let q = Tensor::randn(&[n, d], 1);
        let k = Tensor::randn(&[n, d], 2);
        let v = Tensor::randn(&[n, d], 3);
        let a = run_variant(AttentionVariant::Direct, &q, &k, &v, 1.3);
        let b = run_variant(AttentionVariant::Efficient, &q, &k, &v, 1.3);
        assert!(a.allclose(&b, 1e-4, 1e-4), "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn mhsa_shape_and_head_independence() {
        let (h, n, d) = (4, 16, 8);
        let q = Tensor::randn(&[h, n, d], 4);
        let k = Tensor::randn(&[h, n, d], 5);
        let v = Tensor::randn(&[h, n, d], 6);
        let y = mhsa(AttentionVariant::Efficient, &q, &k, &v, h, 1.0);
        assert_eq!(y.shape(), &[n, h * d]);
        // Head 0 output must equal single-head attention on head-0 slices.
        let q0 = Tensor::new(&[n, d], q.data()[..n * d].to_vec());
        let k0 = Tensor::new(&[n, d], k.data()[..n * d].to_vec());
        let v0 = Tensor::new(&[n, d], v.data()[..n * d].to_vec());
        let y0 = efficient::taylor_efficient(&q0, &k0, &v0, 1.0);
        for i in 0..n {
            for j in 0..d {
                assert!((y.at2(i, j) - y0.at2(i, j)).abs() < 1e-5);
            }
        }
    }
}

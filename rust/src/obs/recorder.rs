//! Flight recorder: a fixed-capacity, lock-free ring of the most
//! recent span/event records. Writers stamp a slot with a seqlock
//! protocol (sequence cleared, payload stored, sequence published);
//! readers double-check the sequence and skip torn slots, so a
//! snapshot never blocks the hot path. Payload fields are atomics, so
//! a torn read is merely skipped — never undefined behaviour.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::collector::SPAN_NAMES;
use super::span::{now_us, Rec, NO_LAYER};
use crate::util::json::Json;

/// What a ring event describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A finished span (name and duration carried in the payload).
    Span,
    /// A request entered a batcher bucket or the decode lane
    /// (`a` = bucket/session, `b` = queue depth after enqueue).
    Enqueue,
    /// The batcher sealed a batch (`a` = batch size, `b` = bucket).
    BatchSeal,
    /// A session crossed N₀ and promoted KV→recurrent.
    Promote,
    /// The store evicted a session (`a` = session id, `b` = bytes).
    Evict,
    /// A typed error surfaced (`a` = error code, `b` = session id).
    Error,
    /// The store evicted a session to a spill file
    /// (`a` = session id, `b` = state bytes parked on disk).
    Spill,
    /// A spilled session was restored on touch
    /// (`a` = session id, `b` = resident bytes rehydrated).
    Restore,
}

impl EventKind {
    fn code(self) -> u64 {
        match self {
            EventKind::Span => 0,
            EventKind::Enqueue => 1,
            EventKind::BatchSeal => 2,
            EventKind::Promote => 3,
            EventKind::Evict => 4,
            EventKind::Error => 5,
            EventKind::Spill => 6,
            EventKind::Restore => 7,
        }
    }

    fn from_code(code: u64) -> Option<EventKind> {
        match code {
            0 => Some(EventKind::Span),
            1 => Some(EventKind::Enqueue),
            2 => Some(EventKind::BatchSeal),
            3 => Some(EventKind::Promote),
            4 => Some(EventKind::Evict),
            5 => Some(EventKind::Error),
            6 => Some(EventKind::Spill),
            7 => Some(EventKind::Restore),
            _ => None,
        }
    }

    /// Stable label used in JSON dumps and the exposition.
    pub fn label(self) -> &'static str {
        match self {
            EventKind::Span => "span",
            EventKind::Enqueue => "enqueue",
            EventKind::BatchSeal => "batch_seal",
            EventKind::Promote => "promote",
            EventKind::Evict => "evict",
            EventKind::Error => "error",
            EventKind::Spill => "spill",
            EventKind::Restore => "restore",
        }
    }
}

/// Error codes carried in an [`EventKind::Error`] event's `a` field.
pub const ERR_EXEC_FAILED: u64 = 1;
pub const ERR_NEEDS_REPREFILL: u64 = 2;
pub const ERR_UNKNOWN_SESSION: u64 = 3;
pub const ERR_SPILL_CORRUPT: u64 = 4;

/// Human label for an error code.
pub fn error_code_label(code: u64) -> &'static str {
    match code {
        ERR_EXEC_FAILED => "exec_failed",
        ERR_NEEDS_REPREFILL => "needs_reprefill",
        ERR_UNKNOWN_SESSION => "unknown_session",
        ERR_SPILL_CORRUPT => "spill_corrupt",
        _ => "unknown",
    }
}

#[derive(Default)]
struct Slot {
    /// 0 while a writer owns the slot; otherwise the 1-based ticket.
    seq: AtomicU64,
    /// `kind << 32 | name_idx << 16 | layer`.
    meta: AtomicU64,
    trace: AtomicU64,
    t_us: AtomicU64,
    dur_us: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

/// One event to push; maps onto the ring slot payload.
#[derive(Clone, Copy)]
pub struct EventRecord {
    pub kind: EventKind,
    pub name_idx: u16,
    pub layer: u16,
    pub trace: u64,
    pub t_us: u64,
    pub dur_us: u64,
    pub a: u64,
    pub b: u64,
}

/// Decoded, owned view of one ring slot.
#[derive(Clone, Copy, Debug)]
pub struct EventView {
    /// 1-based global sequence number (total order of pushes).
    pub seq: u64,
    pub kind: EventKind,
    /// Span name for span events; the kind label otherwise.
    pub name: &'static str,
    pub layer: Option<usize>,
    pub trace: u64,
    pub t_us: u64,
    pub dur_us: u64,
    pub a: u64,
    pub b: u64,
}

/// Fixed-capacity lock-free ring. Capacity is set at construction;
/// pushes wrap and overwrite the oldest slot.
pub struct Ring {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

impl Ring {
    pub fn new(capacity: usize) -> Ring {
        let cap = capacity.max(1);
        Ring {
            head: AtomicU64::new(0),
            slots: (0..cap).map(|_| Slot::default()).collect(),
        }
    }

    /// Record an event; returns its 1-based sequence number.
    pub fn push(&self, rec: EventRecord) -> u64 {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed) + 1;
        let idx = ((ticket - 1) % self.slots.len() as u64) as usize;
        if let Some(slot) = self.slots.get(idx) {
            slot.seq.store(0, Ordering::Release);
            let meta =
                (rec.kind.code() << 32) | ((rec.name_idx as u64) << 16) | rec.layer as u64;
            slot.meta.store(meta, Ordering::Relaxed);
            slot.trace.store(rec.trace, Ordering::Relaxed);
            slot.t_us.store(rec.t_us, Ordering::Relaxed);
            slot.dur_us.store(rec.dur_us, Ordering::Relaxed);
            slot.a.store(rec.a, Ordering::Relaxed);
            slot.b.store(rec.b, Ordering::Relaxed);
            slot.seq.store(ticket, Ordering::Release);
        }
        ticket
    }

    /// Total events ever pushed (monotonic; exceeds capacity once the
    /// ring has wrapped).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Best-effort consistent view of the resident events, oldest
    /// first. Slots being overwritten mid-read are skipped.
    pub fn snapshot(&self) -> Vec<EventView> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let seq1 = slot.seq.load(Ordering::Acquire);
            if seq1 == 0 {
                continue;
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let trace = slot.trace.load(Ordering::Relaxed);
            let t_us = slot.t_us.load(Ordering::Relaxed);
            let dur_us = slot.dur_us.load(Ordering::Relaxed);
            let a = slot.a.load(Ordering::Relaxed);
            let b = slot.b.load(Ordering::Relaxed);
            let seq2 = slot.seq.load(Ordering::Acquire);
            if seq2 != seq1 {
                continue;
            }
            let kind = match EventKind::from_code(meta >> 32) {
                Some(k) => k,
                None => continue,
            };
            let name_idx = ((meta >> 16) & 0xffff) as usize;
            let layer16 = (meta & 0xffff) as u16;
            out.push(EventView {
                seq: seq1,
                kind,
                name: match kind {
                    EventKind::Span => SPAN_NAMES.get(name_idx).copied().unwrap_or("?"),
                    _ => kind.label(),
                },
                layer: if layer16 == NO_LAYER {
                    None
                } else {
                    Some(layer16 as usize)
                },
                trace,
                t_us,
                dur_us,
                a,
                b,
            });
        }
        out.sort_by_key(|e| e.seq);
        out
    }
}

const RING_CAP: usize = 4096;

/// The process-global flight recorder.
pub fn global() -> &'static Ring {
    static GLOBAL: OnceLock<Ring> = OnceLock::new();
    GLOBAL.get_or_init(|| Ring::new(RING_CAP))
}

pub(crate) fn record_span(rec: &Rec) -> u64 {
    global().push(EventRecord {
        kind: EventKind::Span,
        name_idx: rec.name_idx,
        layer: rec.layer,
        trace: rec.trace,
        t_us: rec.start_us,
        dur_us: rec.dur_us,
        a: 0,
        b: 0,
    })
}

/// Push a non-span event into the global ring; returns its sequence
/// number. `a`/`b` meanings are per-kind (see [`EventKind`]).
pub fn record_event(kind: EventKind, trace: u64, a: u64, b: u64) -> u64 {
    global().push(EventRecord {
        kind,
        name_idx: 0,
        layer: NO_LAYER,
        trace,
        t_us: now_us(),
        dur_us: 0,
        a,
        b,
    })
}

/// Push a typed-error event (`code` is one of the `ERR_*` constants).
pub fn record_error(code: u64, trace: u64, session: u64) -> u64 {
    record_event(EventKind::Error, trace, code, session)
}

fn view_json(e: &EventView) -> Json {
    let mut obj = Json::from_pairs(vec![
        ("seq", Json::Num(e.seq as f64)),
        ("kind", Json::Str(e.kind.label().to_string())),
        ("name", Json::Str(e.name.to_string())),
        ("trace", Json::Num(e.trace as f64)),
        ("t_us", Json::Num(e.t_us as f64)),
        ("dur_us", Json::Num(e.dur_us as f64)),
        ("a", Json::Num(e.a as f64)),
        ("b", Json::Num(e.b as f64)),
    ]);
    if let Some(l) = e.layer {
        obj.set("layer", Json::Num(l as f64));
    }
    if e.kind == EventKind::Error {
        obj.set("error", Json::Str(error_code_label(e.a).to_string()));
    }
    obj
}

/// JSON dump of the most recent `limit` resident events (everything
/// resident when `limit` is 0). A nonzero `boundary` keeps only
/// events with `seq <= boundary`, so a dump taken at error time
/// excludes traffic that arrived after the error was recorded.
pub fn dump_json(limit: usize, boundary: u64) -> Json {
    let events = global().snapshot();
    let mut views: Vec<&EventView> = events
        .iter()
        .filter(|e| boundary == 0 || e.seq <= boundary)
        .collect();
    if limit > 0 && views.len() > limit {
        let skip = views.len() - limit;
        views.drain(..skip);
    }
    Json::Arr(views.into_iter().map(view_json).collect())
}

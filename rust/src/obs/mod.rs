//! Observability for the serving stack: lock-free span timers, a
//! flight-recorder ring of recent events, and Prometheus text
//! exposition.
//!
//! The three pillars and how they connect:
//!
//! 1. **Spans** (`span`): `obs::span("engine.exec_batch")` returns an
//!    RAII timer; on drop the record lands in a thread-local buffer,
//!    drained by [`flush`] into the collector and recorder. Trace IDs
//!    minted by [`next_trace_id`] ride along via [`trace_scope`].
//! 2. **Flight recorder** (`recorder`): a fixed-capacity lock-free
//!    ring of recent spans and lifecycle events (enqueue, batch seal,
//!    promotion, eviction, typed error), dumpable as JSON — the
//!    engine dumps it automatically when an error surfaces.
//! 3. **Exposition** (`prometheus`): renders `Metrics::export()` plus
//!    span-derived histograms in Prometheus text format, served via
//!    `Engine::scrape()`.
//!
//! Span naming convention: `<subsystem>.<phase>`, registered in
//! `collector::SPAN_NAMES`. See the ROADMAP's "Observability"
//! section for the propagation rules.

pub mod collector;
pub mod prometheus;
pub mod recorder;
pub mod span;

pub use span::{
    current_trace, flush, next_trace_id, observe, span, span_layer, trace_scope, SpanGuard,
    TraceGuard, NO_LAYER,
};

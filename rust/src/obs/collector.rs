//! Global span collector: per-phase log₂ latency histograms drained
//! from the thread-local span buffers (see `span::flush`). All state
//! is atomics behind a `OnceLock`, so recording is lock-free and the
//! only allocation happens once at warm-up.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::span::{Rec, NO_LAYER};

/// The registered span names. The span API rejects anything else, so
/// the set of exposition series is closed and documented here:
///
/// - `engine.exec_batch` — one padded batch through the executor
/// - `batcher.queue_wait` — submit → batch seal, per request
/// - `lane.queue_wait` — decode submit → lane service, per step
/// - `model.step` — whole-model single-token step, all layers
/// - `model.block_step` — one layer's step (carries a `layer`)
/// - `decode.kv_step` — attention step served on the KV branch
/// - `decode.recurrent_step` — attention step served recurrent
/// - `decode.promote` — one-time KV→recurrent promotion build
/// - `decode.restore` — spill-file read+validate+decode on touch
pub const SPAN_NAMES: [&str; 9] = [
    "engine.exec_batch",
    "batcher.queue_wait",
    "lane.queue_wait",
    "model.step",
    "model.block_step",
    "decode.kv_step",
    "decode.recurrent_step",
    "decode.promote",
    "decode.restore",
];

/// Per-layer histograms kept for `model.block_step`; deeper layers
/// clamp into the last slot.
pub const MAX_LAYER_HISTS: usize = 8;

pub(crate) fn lookup(name: &str) -> Option<usize> {
    SPAN_NAMES.iter().position(|n| *n == name)
}

const HIST_BUCKETS: usize = 32;

/// Lock-free log₂ histogram; bucket i counts durations in
/// `[2^i, 2^(i+1))` microseconds.
struct Hist32 {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Hist32 {
    fn new() -> Self {
        Hist32 {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn record_us(&self, us: u64) {
        let us = us.max(1);
        let idx = (63 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistSnapshot {
        let mut snap = HistSnapshot::default();
        for (out, b) in snap.buckets.iter_mut().zip(self.buckets.iter()) {
            *out = b.load(Ordering::Relaxed);
        }
        snap.sum_us = self.sum_us.load(Ordering::Relaxed);
        snap.count = self.count.load(Ordering::Relaxed);
        snap
    }
}

/// Copy-out view of one log₂ histogram (`buckets[i]` counts samples
/// in `[2^i, 2^(i+1))` µs). Shared between the span collector and
/// `coordinator::metrics::LatencyHistogram` so the Prometheus
/// renderer has a single histogram input type.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; 32],
    pub sum_us: u64,
    pub count: u64,
}

struct Collector {
    span_hists: [Hist32; SPAN_NAMES.len()],
    layer_hists: [Hist32; MAX_LAYER_HISTS],
    spans_recorded: AtomicU64,
    spans_dropped: AtomicU64,
    unknown_spans: AtomicU64,
}

impl Collector {
    fn new() -> Self {
        Collector {
            span_hists: std::array::from_fn(|_| Hist32::new()),
            layer_hists: std::array::from_fn(|_| Hist32::new()),
            spans_recorded: AtomicU64::new(0),
            spans_dropped: AtomicU64::new(0),
            unknown_spans: AtomicU64::new(0),
        }
    }
}

fn global() -> &'static Collector {
    static GLOBAL: OnceLock<Collector> = OnceLock::new();
    GLOBAL.get_or_init(Collector::new)
}

pub(crate) fn observe_rec(rec: &Rec) {
    let g = global();
    if let Some(h) = g.span_hists.get(rec.name_idx as usize) {
        h.record_us(rec.dur_us);
    }
    if rec.layer != NO_LAYER {
        let l = (rec.layer as usize).min(MAX_LAYER_HISTS - 1);
        if let Some(h) = g.layer_hists.get(l) {
            h.record_us(rec.dur_us);
        }
    }
    g.spans_recorded.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn note_dropped() {
    global().spans_dropped.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn note_unknown() {
    global().unknown_spans.fetch_add(1, Ordering::Relaxed);
}

/// Snapshot of the histogram for `SPAN_NAMES[idx]` (empty snapshot
/// for out-of-range indices).
pub fn span_snapshot(idx: usize) -> HistSnapshot {
    global()
        .span_hists
        .get(idx)
        .map(Hist32::snapshot)
        .unwrap_or_default()
}

/// Snapshot of the per-layer `model.block_step` histogram.
pub fn layer_snapshot(layer: usize) -> HistSnapshot {
    global()
        .layer_hists
        .get(layer.min(MAX_LAYER_HISTS - 1))
        .map(Hist32::snapshot)
        .unwrap_or_default()
}

/// `(recorded, dropped, unknown)` span meta counters.
pub fn meta_counters() -> (u64, u64, u64) {
    let g = global();
    (
        g.spans_recorded.load(Ordering::Relaxed),
        g.spans_dropped.load(Ordering::Relaxed),
        g.unknown_spans.load(Ordering::Relaxed),
    )
}

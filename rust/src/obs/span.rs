//! Lock-free RAII span timers with per-request trace propagation.
//!
//! This is the decode hot path's instrumentation layer, so the
//! discipline here is machine-checked by taylor-lint rule R6: no
//! locks and no allocation. Finished spans land in a fixed-size
//! thread-local buffer; [`flush`] (or a full buffer) drains them into
//! the global collector histograms and the flight recorder ring, both
//! of which are atomics-only.
//!
//! Trace IDs are plain `u64`s minted by [`next_trace_id`]. The engine
//! installs a request's trace on the worker thread via [`trace_scope`]
//! before stepping it, so every span opened underneath — branch
//! dispatch, per-layer block steps, promotion — carries the same ID
//! without any plumbing through the model code.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use super::collector;
use super::recorder;

/// Layer field value meaning "not layer-scoped".
pub const NO_LAYER: u16 = u16::MAX;

/// One finished span, staged in the thread-local buffer.
#[derive(Clone, Copy)]
pub(crate) struct Rec {
    pub name_idx: u16,
    pub layer: u16,
    pub trace: u64,
    pub start_us: u64,
    pub dur_us: u64,
}

const EMPTY_REC: Rec = Rec {
    name_idx: 0,
    layer: NO_LAYER,
    trace: 0,
    start_us: 0,
    dur_us: 0,
};

const BUF_CAP: usize = 64;

struct Buf {
    recs: [Rec; BUF_CAP],
    len: usize,
}

thread_local! {
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
    static BUF: RefCell<Buf> = const {
        RefCell::new(Buf {
            recs: [EMPTY_REC; BUF_CAP],
            len: 0,
        })
    };
}

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Mint a fresh, process-unique, nonzero trace ID.
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// The trace ID installed on this thread (0 when none).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.try_with(Cell::get).unwrap_or(0)
}

/// RAII guard restoring the previously installed trace on drop.
pub struct TraceGuard {
    prev: u64,
}

/// Install `trace` as this thread's current trace until the returned
/// guard drops; spans opened meanwhile inherit it.
pub fn trace_scope(trace: u64) -> TraceGuard {
    let prev = CURRENT_TRACE.try_with(|c| c.replace(trace)).unwrap_or(0);
    TraceGuard { prev }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let _ = CURRENT_TRACE.try_with(|c| c.set(self.prev));
    }
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process-local observability epoch.
pub(crate) fn now_us() -> u64 {
    Instant::now().duration_since(epoch()).as_micros() as u64
}

/// RAII timer: records a span for its registered phase on drop.
pub struct SpanGuard {
    name_idx: u16,
    layer: u16,
    trace: u64,
    start: Instant,
    armed: bool,
}

/// Start a span for a registered phase name (one of
/// `collector::SPAN_NAMES`). Unknown names disarm the guard and bump
/// a counter instead of recording, so a typo cannot grow state.
pub fn span(name: &'static str) -> SpanGuard {
    span_layer(name, NO_LAYER)
}

/// Start a span attributed to a model layer (clamped into the
/// collector's per-layer histogram range at record time).
pub fn span_layer(name: &'static str, layer: u16) -> SpanGuard {
    match collector::lookup(name) {
        Some(idx) => SpanGuard {
            name_idx: idx as u16,
            layer,
            trace: current_trace(),
            start: Instant::now(),
            armed: true,
        },
        None => {
            collector::note_unknown();
            SpanGuard {
                name_idx: 0,
                layer,
                trace: 0,
                start: Instant::now(),
                armed: false,
            }
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur_us = self.start.elapsed().as_micros() as u64;
        push_rec(Rec {
            name_idx: self.name_idx,
            layer: self.layer,
            trace: self.trace,
            start_us: now_us().saturating_sub(dur_us),
            dur_us,
        });
    }
}

/// Record an externally measured duration (e.g. queue wait computed
/// from an enqueue timestamp) against a registered span name.
pub fn observe(name: &'static str, dur: Duration, trace: u64) {
    match collector::lookup(name) {
        Some(idx) => {
            let dur_us = dur.as_micros() as u64;
            push_rec(Rec {
                name_idx: idx as u16,
                layer: NO_LAYER,
                trace,
                start_us: now_us().saturating_sub(dur_us),
                dur_us,
            });
        }
        None => collector::note_unknown(),
    }
}

fn push_rec(rec: Rec) {
    let pushed = BUF
        .try_with(|buf| {
            if let Ok(mut b) = buf.try_borrow_mut() {
                if b.len == BUF_CAP {
                    drain(&mut b);
                }
                let len = b.len;
                if let Some(slot) = b.recs.get_mut(len) {
                    *slot = rec;
                    b.len = len + 1;
                    return true;
                }
            }
            false
        })
        .unwrap_or(false);
    if !pushed {
        collector::note_dropped();
    }
}

fn drain(b: &mut Buf) {
    for rec in b.recs.iter().take(b.len) {
        collector::observe_rec(rec);
        recorder::record_span(rec);
    }
    b.len = 0;
}

/// Drain this thread's span buffer into the collector and recorder.
/// The engine calls this before answering a waiter, so a blocking
/// caller observes its complete trace in the flight recorder.
pub fn flush() {
    let _ = BUF.try_with(|buf| {
        if let Ok(mut b) = buf.try_borrow_mut() {
            drain(&mut b);
        }
    });
}

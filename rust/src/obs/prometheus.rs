//! Prometheus text-format exposition over the metrics and span
//! surfaces, plus a strict parser/validator used by the scrape_check
//! example and the integration tests.
//!
//! Counters and gauges come from `Metrics::export()`; histograms are
//! rendered natively (`_bucket`/`_sum`/`_count` with cumulative `le`
//! bounds at the log₂ bucket edges) from `Metrics::histogram_list()`
//! and the span collector, with `layer`, `span`, and `branch` as
//! labels. All series share the `taylorshift_` prefix.

use std::fmt::Write as _;

use super::collector::{self, HistSnapshot, MAX_LAYER_HISTS, SPAN_NAMES};
use super::recorder;
use crate::coordinator::metrics::{Metrics, SampleKind};

const PREFIX: &str = "taylorshift_";

/// Label block for unlabelled families. Named (rather than a literal
/// at the call sites) so taylor-lint R5 reads the metric name as the
/// first string argument of every `register_*` call.
const NO_LABELS: &str = "";

/// Incremental exposition writer that emits each family's `# TYPE`
/// header exactly once, before its first series.
struct Expo {
    out: String,
    typed: Vec<String>,
}

impl Expo {
    fn new() -> Expo {
        Expo {
            out: String::new(),
            typed: Vec::new(),
        }
    }

    fn type_line(&mut self, name: &str, kind: &str) {
        if self.typed.iter().any(|n| n == name) {
            return;
        }
        let _ = writeln!(self.out, "# TYPE {PREFIX}{name} {kind}");
        self.typed.push(name.to_string());
    }

    fn register_counter(&mut self, name: &str, value: f64) {
        self.type_line(name, "counter");
        let _ = writeln!(self.out, "{PREFIX}{name} {value}");
    }

    fn register_gauge(&mut self, name: &str, labels: &str, value: f64) {
        self.type_line(name, "gauge");
        if labels.is_empty() {
            let _ = writeln!(self.out, "{PREFIX}{name} {value}");
        } else {
            let _ = writeln!(self.out, "{PREFIX}{name}{{{labels}}} {value}");
        }
    }

    /// Native histogram series from a log₂ snapshot: bucket i's upper
    /// bound is 2^(i+1) µs; `+Inf` and `_count` are both the bucket
    /// total so the family is self-consistent even when the snapshot
    /// raced a writer.
    fn register_histogram(&mut self, name: &str, labels: &str, snap: &HistSnapshot) {
        self.type_line(name, "histogram");
        let mut cum = 0u64;
        for (i, c) in snap.buckets.iter().enumerate() {
            cum += c;
            let le = 1u64 << (i + 1);
            if labels.is_empty() {
                let _ = writeln!(self.out, "{PREFIX}{name}_bucket{{le=\"{le}\"}} {cum}");
            } else {
                let _ = writeln!(
                    self.out,
                    "{PREFIX}{name}_bucket{{{labels},le=\"{le}\"}} {cum}"
                );
            }
        }
        let (blabel, sep) = if labels.is_empty() {
            (String::new(), "")
        } else {
            (labels.to_string(), ",")
        };
        let _ = writeln!(
            self.out,
            "{PREFIX}{name}_bucket{{{blabel}{sep}le=\"+Inf\"}} {cum}"
        );
        if labels.is_empty() {
            let _ = writeln!(self.out, "{PREFIX}{name}_sum {}", snap.sum_us);
            let _ = writeln!(self.out, "{PREFIX}{name}_count {cum}");
        } else {
            let _ = writeln!(self.out, "{PREFIX}{name}_sum{{{labels}}} {}", snap.sum_us);
            let _ = writeln!(self.out, "{PREFIX}{name}_count{{{labels}}} {cum}");
        }
    }
}

/// Render the full exposition: counters/gauges from
/// [`Metrics::export`], native histograms from the metrics and span
/// collector, per-layer and per-branch step timing, and the
/// observability meta counters.
pub fn render(metrics: &Metrics) -> String {
    let mut e = Expo::new();

    for s in metrics.export() {
        let labels = match s.layer {
            Some(l) => format!("layer=\"{l}\""),
            None => String::new(),
        };
        match s.kind {
            SampleKind::Counter => e.register_counter(s.name, s.value),
            SampleKind::Gauge => e.register_gauge(s.name, &labels, s.value),
            // Histogram-derived scalars (p50/p99/mean/count) are
            // superseded by the native series below.
            SampleKind::Histogram => {}
        }
    }

    for (name, h) in metrics.histogram_list() {
        let snap = h.snapshot();
        e.register_histogram(name, NO_LABELS, &snap);
    }

    for (i, span_name) in SPAN_NAMES.iter().enumerate() {
        let snap = collector::span_snapshot(i);
        let labels = format!("span=\"{span_name}\"");
        e.register_histogram("span_time_us", &labels, &snap);
    }

    for l in 0..MAX_LAYER_HISTS {
        let snap = collector::layer_snapshot(l);
        if snap.count == 0 {
            continue;
        }
        let labels = format!("layer=\"{l}\"");
        e.register_histogram("layer_step_time_us", &labels, &snap);
    }

    let kv = collector::span_snapshot(collector::lookup("decode.kv_step").unwrap_or(0));
    e.register_histogram("decode_branch_step_time_us", "branch=\"kv\"", &kv);
    let rec = collector::span_snapshot(collector::lookup("decode.recurrent_step").unwrap_or(0));
    e.register_histogram("decode_branch_step_time_us", "branch=\"recurrent\"", &rec);

    let (recorded, dropped, unknown) = collector::meta_counters();
    e.register_counter("obs_spans_recorded_total", recorded as f64);
    e.register_counter("obs_spans_dropped_total", dropped as f64);
    e.register_counter("obs_unknown_spans_total", unknown as f64);
    e.register_counter("obs_events_total", recorder::global().pushed() as f64);

    e.out
}

/// Counts extracted by [`validate_exposition`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExpoStats {
    /// `# TYPE` families declared.
    pub types: usize,
    /// Sample lines parsed.
    pub series: usize,
    /// Distinct histogram (name, label-set) groups checked.
    pub histograms: usize,
}

fn name_ok(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split a sample line into (name, labels, value-text), honouring
/// quotes inside the label block.
fn split_series(line: &str) -> Result<(&str, &str, &str), String> {
    if let Some(open) = line.find('{') {
        let name = &line[..open];
        let rest = &line[open + 1..];
        let mut in_quotes = false;
        let mut escaped = false;
        for (i, c) in rest.char_indices() {
            if escaped {
                escaped = false;
                continue;
            }
            match c {
                '\\' if in_quotes => escaped = true,
                '"' => in_quotes = !in_quotes,
                '}' if !in_quotes => {
                    let labels = &rest[..i];
                    let value = rest[i + 1..].trim();
                    return Ok((name, labels, value));
                }
                _ => {}
            }
        }
        Err("unterminated label block".into())
    } else {
        match line.split_once(' ') {
            Some((name, value)) => Ok((name, "", value.trim())),
            None => Err("sample line has no value".into()),
        }
    }
}

/// Parse a label block into (key, value) pairs.
fn parse_labels(labels: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let mut rest = labels.trim();
    while !rest.is_empty() {
        let eq = rest.find('=').ok_or_else(|| "label without '='".to_string())?;
        let key = rest[..eq].trim().to_string();
        let after = rest[eq + 1..].trim_start();
        if !after.starts_with('"') {
            return Err(format!("label `{key}` value is not quoted"));
        }
        let body = &after[1..];
        let mut end = None;
        let mut escaped = false;
        for (i, c) in body.char_indices() {
            if escaped {
                escaped = false;
                continue;
            }
            match c {
                '\\' => escaped = true,
                '"' => {
                    end = Some(i);
                    break;
                }
                _ => {}
            }
        }
        let end = end.ok_or_else(|| format!("label `{key}` value is unterminated"))?;
        out.push((key, body[..end].to_string()));
        rest = body[end + 1..].trim_start();
        if let Some(stripped) = rest.strip_prefix(',') {
            rest = stripped.trim_start();
        } else if !rest.is_empty() {
            return Err("junk after label value".into());
        }
    }
    Ok(out)
}

fn strip_hist_suffix(name: &str) -> Option<(&str, &str)> {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            return Some((base, suffix));
        }
    }
    None
}

struct HistGroup {
    key: String,
    buckets: Vec<(f64, f64)>,
    count: Option<f64>,
}

/// Validate a Prometheus text exposition: every series' family must
/// have a preceding `# TYPE` header, names must be legal, values must
/// parse, and every histogram group must have ascending `le` bounds,
/// monotone cumulative counts, and a `+Inf` bucket equal to `_count`.
pub fn validate_exposition(text: &str) -> Result<ExpoStats, String> {
    let mut types: Vec<(String, String)> = Vec::new();
    let mut stats = ExpoStats::default();
    let mut groups: Vec<HistGroup> = Vec::new();

    for (lineno0, raw) in text.lines().enumerate() {
        let lineno = lineno0 + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(decl) = line.strip_prefix("# TYPE ") {
            let mut parts = decl.split_whitespace();
            let (name, kind) = match (parts.next(), parts.next(), parts.next()) {
                (Some(n), Some(k), None) => (n, k),
                _ => return Err(format!("line {lineno}: malformed TYPE header")),
            };
            if !name_ok(name) {
                return Err(format!("line {lineno}: illegal metric name `{name}`"));
            }
            if !["counter", "gauge", "histogram", "summary", "untyped"].contains(&kind) {
                return Err(format!("line {lineno}: unknown metric type `{kind}`"));
            }
            if types.iter().any(|(n, _)| n == name) {
                return Err(format!("line {lineno}: duplicate TYPE for `{name}`"));
            }
            types.push((name.to_string(), kind.to_string()));
            continue;
        }
        if line.starts_with('#') {
            continue;
        }

        let (name, labels, value_text) =
            split_series(line).map_err(|e| format!("line {lineno}: {e}"))?;
        if !name_ok(name) {
            return Err(format!("line {lineno}: illegal series name `{name}`"));
        }
        let value: f64 = if value_text == "+Inf" {
            f64::INFINITY
        } else {
            value_text
                .parse()
                .map_err(|_| format!("line {lineno}: unparseable value `{value_text}`"))?
        };
        let pairs = parse_labels(labels).map_err(|e| format!("line {lineno}: {e}"))?;

        // Resolve the declaring family: the series name itself, or
        // the base name for histogram component series.
        let declared_kind = types
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, k)| k.as_str());
        let hist_base = strip_hist_suffix(name).and_then(|(base, suffix)| {
            let is_hist = types
                .iter()
                .any(|(n, k)| n == base && (k == "histogram" || k == "summary"));
            if is_hist {
                Some((base, suffix))
            } else {
                None
            }
        });
        if declared_kind.is_none() && hist_base.is_none() {
            return Err(format!(
                "line {lineno}: series `{name}` has no preceding TYPE header"
            ));
        }
        stats.series += 1;

        if let Some((base, suffix)) = hist_base {
            let mut le = None;
            let mut rest: Vec<String> = Vec::new();
            for (k, v) in &pairs {
                if k == "le" {
                    le = Some(v.clone());
                } else {
                    rest.push(format!("{k}={v}"));
                }
            }
            rest.sort();
            let key = format!("{base}|{}", rest.join(","));
            let idx = match groups.iter().position(|g| g.key == key) {
                Some(i) => i,
                None => {
                    groups.push(HistGroup {
                        key,
                        buckets: Vec::new(),
                        count: None,
                    });
                    groups.len() - 1
                }
            };
            match suffix {
                "_bucket" => {
                    let le = le.ok_or_else(|| {
                        format!("line {lineno}: `{name}` bucket without an `le` label")
                    })?;
                    let bound = if le == "+Inf" {
                        f64::INFINITY
                    } else {
                        le.parse().map_err(|_| {
                            format!("line {lineno}: unparseable le bound `{le}`")
                        })?
                    };
                    if let Some(g) = groups.get_mut(idx) {
                        g.buckets.push((bound, value));
                    }
                }
                "_count" => {
                    if let Some(g) = groups.get_mut(idx) {
                        g.count = Some(value);
                    }
                }
                _ => {}
            }
        }
    }

    for g in &groups {
        let mut prev_bound = f64::NEG_INFINITY;
        let mut prev_count = -1.0f64;
        for (bound, count) in &g.buckets {
            if *bound <= prev_bound {
                return Err(format!(
                    "histogram group `{}`: le bounds not ascending",
                    g.key
                ));
            }
            if *count < prev_count {
                return Err(format!(
                    "histogram group `{}`: bucket counts not monotone",
                    g.key
                ));
            }
            prev_bound = *bound;
            prev_count = *count;
        }
        let inf = g
            .buckets
            .last()
            .filter(|(bound, _)| bound.is_infinite())
            .map(|(_, count)| *count)
            .ok_or_else(|| format!("histogram group `{}`: missing +Inf bucket", g.key))?;
        if let Some(count) = g.count {
            if (count - inf).abs() > 0.0 {
                return Err(format!(
                    "histogram group `{}`: +Inf bucket {inf} != _count {count}",
                    g.key
                ));
            }
        }
    }

    stats.types = types.len();
    stats.histograms = groups.len();
    Ok(stats)
}

//! Synthetic pixel-sequence classification — the CIFAR10-pixel
//! substitute (DESIGN.md §5).
//!
//! The LRA CIFAR task feeds 8-bit grayscale pixel intensities of a
//! 32×32 image as a length-1024 token sequence; the model must learn
//! 2-D structure from the 1-D serialization. We preserve exactly that
//! regime with procedurally drawn grayscale shapes (disk, square,
//! cross, stripes) on noisy backgrounds: 8-bit intensity tokens,
//! row-major serialization, class = shape. Scaled to 16×16 (N=256) for
//! the CPU budget; side is configurable.

use super::{Example, TaskGenerator};
use crate::util::rng::Pcg64;

/// Shape classes.
pub const CLASSES: [&str; 4] = ["disk", "square", "cross", "stripes"];

#[derive(Clone, Debug)]
pub struct PixelGen {
    /// Image side length; sequence length is side².
    pub side: usize,
    /// Background noise amplitude (0-255 scale).
    pub noise: f64,
}

impl Default for PixelGen {
    fn default() -> Self {
        Self { side: 16, noise: 24.0 }
    }
}

impl PixelGen {
    pub fn seq_len(&self) -> usize {
        self.side * self.side
    }

    /// Render one image as u8 intensities.
    pub fn render(&self, rng: &mut Pcg64, class: usize) -> Vec<u8> {
        let s = self.side as f64;
        let mut img = vec![0.0f64; self.side * self.side];
        // Noisy background.
        let bg = 40.0 + 40.0 * rng.next_f64();
        for px in img.iter_mut() {
            *px = bg + self.noise * rng.next_gaussian();
        }
        // Foreground shape with random center/size/intensity.
        let fg = 170.0 + 60.0 * rng.next_f64();
        let cx = s * (0.35 + 0.3 * rng.next_f64());
        let cy = s * (0.35 + 0.3 * rng.next_f64());
        let r = s * (0.18 + 0.12 * rng.next_f64());
        for y in 0..self.side {
            for x in 0..self.side {
                let (fx, fy) = (x as f64 + 0.5, y as f64 + 0.5);
                let inside = match class {
                    0 => (fx - cx).powi(2) + (fy - cy).powi(2) <= r * r, // disk
                    1 => (fx - cx).abs() <= r && (fy - cy).abs() <= r,   // square
                    2 => {
                        // cross: two perpendicular bars
                        let bar = r * 0.45;
                        ((fx - cx).abs() <= bar && (fy - cy).abs() <= r * 1.4)
                            || ((fy - cy).abs() <= bar && (fx - cx).abs() <= r * 1.4)
                    }
                    3 => {
                        // stripes: periodic vertical bands (global texture —
                        // forces long-range structure in the 1-D serialization)
                        let period = (s / 4.0).max(2.0);
                        ((fx / period).floor() as i64) % 2 == 0
                    }
                    _ => unreachable!(),
                };
                if inside {
                    img[y * self.side + x] = fg + self.noise * 0.5 * rng.next_gaussian();
                }
            }
        }
        img.into_iter()
            .map(|v| v.clamp(0.0, 255.0) as u8)
            .collect()
    }
}

impl TaskGenerator for PixelGen {
    fn vocab(&self) -> usize {
        256
    }

    fn classes(&self) -> usize {
        CLASSES.len()
    }

    fn generate(&self, rng: &mut Pcg64) -> Example {
        let class = rng.next_below(CLASSES.len() as u64) as usize;
        let pixels = self.render(rng, class);
        Example {
            tokens: pixels.into_iter().map(|p| p as i32).collect(),
            label: class as i32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_length_is_side_squared() {
        let g = PixelGen::default();
        let mut rng = Pcg64::new(1);
        let ex = g.generate(&mut rng);
        assert_eq!(ex.tokens.len(), 256);
        assert!(ex.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn all_classes_generated() {
        let g = PixelGen::default();
        let mut rng = Pcg64::new(2);
        let mut seen = [false; 4];
        for _ in 0..100 {
            seen[g.generate(&mut rng).label as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shapes_are_statistically_distinguishable() {
        // Foreground pixels should raise the mean intensity vs a pure
        // background; stripes cover ~half the image.
        let g = PixelGen { side: 16, noise: 8.0 };
        let mut rng = Pcg64::new(3);
        let mean = |img: &[u8]| img.iter().map(|&x| x as f64).sum::<f64>() / img.len() as f64;
        let disk = g.render(&mut rng, 0);
        let stripes = g.render(&mut rng, 3);
        assert!(mean(&stripes) > mean(&disk), "stripes cover more area");
    }

    #[test]
    fn deterministic_per_seed() {
        let g = PixelGen::default();
        let a = g.generate(&mut Pcg64::new(7));
        let b = g.generate(&mut Pcg64::new(7));
        assert_eq!(a, b);
    }
}

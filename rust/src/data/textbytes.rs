//! Synthetic byte-level text classification — the IMDB-Byte substitute
//! (DESIGN.md §5).
//!
//! IMDB-Byte classifies movie-review sentiment from raw bytes at
//! N=4000. We preserve the regime — byte-level vocabulary (256),
//! long cut/padded sequences, class signal spread across the whole
//! document — with a two-class stochastic grammar: each class has its
//! own word distribution (distinct stems and function-word mixture) so
//! the classifier must integrate weak evidence over many tokens rather
//! than key on one marker.

use super::{Example, TaskGenerator};
use crate::util::rng::Pcg64;

const CLASS_A_STEMS: [&str; 12] = [
    "lumin", "brill", "superb", "delight", "charm", "master", "vivid", "tender", "crisp",
    "elegant", "radiant", "sincere",
];
const CLASS_B_STEMS: [&str; 12] = [
    "dismal", "tediou", "clumsy", "dreary", "shallow", "murky", "stale", "wooden", "leaden",
    "garish", "listless", "hollow",
];
const NEUTRAL: [&str; 16] = [
    "the", "a", "of", "and", "to", "in", "it", "was", "film", "scene", "plot", "actor", "story",
    "with", "for", "that",
];
const SUFFIXES: [&str; 6] = ["", "ly", "ing", "ed", "ous", "ness"];

#[derive(Clone, Debug)]
pub struct TextBytesGen {
    /// Target byte length (sequences are cut/padded to this, mirroring
    /// the LRA pipeline).
    pub seq_len: usize,
    /// Fraction of words drawn from the class-specific stem pool.
    pub signal_rate: f64,
}

impl Default for TextBytesGen {
    fn default() -> Self {
        Self { seq_len: 512, signal_rate: 0.18 }
    }
}

impl TextBytesGen {
    /// Produce the raw text of one document.
    pub fn document(&self, rng: &mut Pcg64, class: usize) -> String {
        let stems: &[&str] = if class == 0 { &CLASS_A_STEMS } else { &CLASS_B_STEMS };
        let mut text = String::with_capacity(self.seq_len + 16);
        while text.len() < self.seq_len + 8 {
            let word = if rng.bernoulli(self.signal_rate) {
                format!("{}{}", rng.choice(stems), rng.choice(&SUFFIXES))
            } else {
                rng.choice(&NEUTRAL).to_string()
            };
            text.push_str(&word);
            // Occasional punctuation, otherwise space.
            if rng.bernoulli(0.06) {
                text.push_str(". ");
            } else {
                text.push(' ');
            }
        }
        text
    }
}

impl TaskGenerator for TextBytesGen {
    fn vocab(&self) -> usize {
        256
    }

    fn classes(&self) -> usize {
        2
    }

    fn generate(&self, rng: &mut Pcg64) -> Example {
        let class = rng.next_below(2) as usize;
        let text = self.document(rng, class);
        let mut tokens: Vec<i32> = text.bytes().map(|b| b as i32).collect();
        tokens.truncate(self.seq_len); // cut (padding happens in batch.rs)
        Example { tokens, label: class as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_range_and_length() {
        let g = TextBytesGen::default();
        let mut rng = Pcg64::new(1);
        let ex = g.generate(&mut rng);
        assert_eq!(ex.tokens.len(), 512);
        assert!(ex.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn classes_have_distinct_vocabulary() {
        let g = TextBytesGen::default();
        let mut rng = Pcg64::new(2);
        let doc_a = g.document(&mut rng, 0);
        let doc_b = g.document(&mut rng, 1);
        let has_a = CLASS_A_STEMS.iter().any(|s| doc_a.contains(s));
        let has_b_in_a = CLASS_B_STEMS.iter().any(|s| doc_a.contains(s));
        assert!(has_a && !has_b_in_a);
        assert!(CLASS_B_STEMS.iter().any(|s| doc_b.contains(s)));
    }

    #[test]
    fn signal_is_distributed_not_localized() {
        // Split a doc in half: both halves should carry class stems, so
        // the classifier can't shortcut on a prefix.
        let g = TextBytesGen { seq_len: 1024, signal_rate: 0.18 };
        let mut rng = Pcg64::new(3);
        let doc = g.document(&mut rng, 0);
        let mid = doc.len() / 2;
        let first = &doc[..mid];
        let second = &doc[mid..];
        assert!(CLASS_A_STEMS.iter().any(|s| first.contains(s)));
        assert!(CLASS_A_STEMS.iter().any(|s| second.contains(s)));
    }

    #[test]
    fn both_labels_occur() {
        let g = TextBytesGen::default();
        let mut rng = Pcg64::new(4);
        let labels: Vec<i32> = (0..40).map(|_| g.generate(&mut rng).label).collect();
        assert!(labels.contains(&0) && labels.contains(&1));
    }
}

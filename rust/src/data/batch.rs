//! Batch assembly: padding, truncation, length bucketing.
//!
//! Shared by the trainer (fixed-shape batches for the train-step
//! executables) and the serving coordinator (bucket selection for
//! variable-length requests).

use super::{Example, TaskGenerator};
use crate::util::rng::Pcg64;

/// A model-ready rectangular batch.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    /// (B, N) row-major token ids.
    pub tokens: Vec<Vec<i32>>,
    pub labels: Vec<i32>,
    pub seq_len: usize,
}

impl Batch {
    pub fn size(&self) -> usize {
        self.tokens.len()
    }
}

/// Pad (with `pad_id`) or truncate a token sequence to exactly `n`.
pub fn fit_length(tokens: &[i32], n: usize, pad_id: i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(n);
    out.extend(tokens.iter().take(n).copied());
    out.resize(n, pad_id);
    out
}

/// Assemble a batch of examples at fixed length `n`.
pub fn collate(examples: &[Example], n: usize, pad_id: i32) -> Batch {
    Batch {
        tokens: examples
            .iter()
            .map(|e| fit_length(&e.tokens, n, pad_id))
            .collect(),
        labels: examples.iter().map(|e| e.label).collect(),
        seq_len: n,
    }
}

/// Generate a fresh batch from a task generator.
pub fn generate_batch<G: TaskGenerator>(
    gen: &G,
    rng: &mut Pcg64,
    batch: usize,
    n: usize,
) -> Batch {
    let examples: Vec<Example> = (0..batch).map(|_| gen.generate(rng)).collect();
    collate(&examples, n, gen.pad_id())
}

/// Length buckets for the serving path: the smallest configured bucket
/// that fits, or `None` if the sequence exceeds the largest bucket.
#[derive(Clone, Debug)]
pub struct Buckets {
    sizes: Vec<usize>,
}

impl Buckets {
    pub fn new(mut sizes: Vec<usize>) -> Self {
        assert!(!sizes.is_empty(), "need at least one bucket");
        sizes.sort_unstable();
        sizes.dedup();
        Self { sizes }
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    pub fn largest(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Smallest bucket >= len.
    pub fn select(&self, len: usize) -> Option<usize> {
        self.sizes.iter().copied().find(|&b| b >= len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::listops::ListOpsGen;
    use crate::testing::prop::{pair, run, Config, Gen};

    #[test]
    fn fit_length_pads_and_truncates() {
        assert_eq!(fit_length(&[1, 2, 3], 5, 0), vec![1, 2, 3, 0, 0]);
        assert_eq!(fit_length(&[1, 2, 3, 4, 5, 6], 4, 0), vec![1, 2, 3, 4]);
        assert_eq!(fit_length(&[], 3, 9), vec![9, 9, 9]);
    }

    #[test]
    fn collate_is_rectangular() {
        let g = ListOpsGen { min_len: 8, max_len: 60, ..Default::default() };
        let mut rng = Pcg64::new(1);
        let b = generate_batch(&g, &mut rng, 7, 64);
        assert_eq!(b.size(), 7);
        assert_eq!(b.labels.len(), 7);
        assert!(b.tokens.iter().all(|row| row.len() == 64));
    }

    #[test]
    fn buckets_select_smallest_fit() {
        let b = Buckets::new(vec![512, 128, 256, 1024]);
        assert_eq!(b.select(1), Some(128));
        assert_eq!(b.select(128), Some(128));
        assert_eq!(b.select(129), Some(256));
        assert_eq!(b.select(1024), Some(1024));
        assert_eq!(b.select(1025), None);
        assert_eq!(b.largest(), 1024);
    }

    #[test]
    fn prop_bucket_is_tight() {
        // Selected bucket fits, and no smaller configured bucket does.
        let buckets = Buckets::new(vec![64, 128, 256, 512]);
        run(
            Config::default().cases(256),
            Gen::usize_range(1, 600),
            move |&len| match buckets.select(len) {
                Some(b) => {
                    b >= len && buckets.sizes().iter().all(|&s| s >= b || s < len)
                }
                None => len > buckets.largest(),
            },
        );
    }

    #[test]
    fn prop_fit_length_exact() {
        run(
            Config::default().cases(128),
            pair(Gen::usize_range(0, 300), Gen::usize_range(1, 300)),
            |&(src_len, n)| {
                let tokens: Vec<i32> = (0..src_len as i32).collect();
                let fitted = fit_length(&tokens, n, -1);
                fitted.len() == n
                    && fitted
                        .iter()
                        .enumerate()
                        .all(|(i, &t)| if i < src_len.min(n) { t == i as i32 } else { t == -1 })
            },
        );
    }
}

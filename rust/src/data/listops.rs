//! Long ListOps (Nangia & Bowman 2018; LRA variant, Tay et al. 2021).
//!
//! Nested prefix expressions over digits 0-9 with operators MIN, MAX,
//! MED(ian), SM (sum mod 10), FIRST and LAST, e.g.
//! `[MAX 2 9 [MIN 4 7 ] 0 ]` → 9. The answer is always a digit, making
//! it a 10-way classification task. This is the one *real* dataset of
//! the paper's evaluation we can regenerate exactly: the original is
//! itself procedurally generated; we implement the generator (nesting
//! depth ≤ 10, configurable length band) and an exact recursive
//! evaluator used both for labels and as a test oracle.

use super::{Example, TaskGenerator};
use crate::util::rng::Pcg64;

/// Operators, in token-id order.
pub const OPERATORS: [Op; 6] = [Op::Min, Op::Max, Op::Med, Op::Sm, Op::First, Op::Last];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Min,
    Max,
    Med,
    Sm,
    First,
    Last,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Min => "[MIN",
            Op::Max => "[MAX",
            Op::Med => "[MED",
            Op::Sm => "[SM",
            Op::First => "[FIRST",
            Op::Last => "[LAST",
        }
    }

    pub fn apply(&self, args: &[u8]) -> u8 {
        assert!(!args.is_empty());
        match self {
            Op::Min => *args.iter().min().unwrap(),
            Op::Max => *args.iter().max().unwrap(),
            Op::Med => {
                let mut sorted = args.to_vec();
                sorted.sort_unstable();
                // LRA convention: lower median for even counts.
                sorted[(sorted.len() - 1) / 2]
            }
            Op::Sm => (args.iter().map(|&x| x as u32).sum::<u32>() % 10) as u8,
            Op::First => args[0],
            Op::Last => *args.last().unwrap(),
        }
    }
}

/// Expression tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Digit(u8),
    Apply(Op, Vec<Expr>),
}

impl Expr {
    /// Exact evaluation (the label oracle).
    pub fn eval(&self) -> u8 {
        match self {
            Expr::Digit(d) => *d,
            Expr::Apply(op, args) => {
                let vals: Vec<u8> = args.iter().map(Expr::eval).collect();
                op.apply(&vals)
            }
        }
    }

    /// Render as the canonical space-separated string form.
    pub fn render(&self) -> String {
        match self {
            Expr::Digit(d) => d.to_string(),
            Expr::Apply(op, args) => {
                let mut s = op.name().to_string();
                for a in args {
                    s.push(' ');
                    s.push_str(&a.render());
                }
                s.push_str(" ]");
                s
            }
        }
    }

    /// Token count of the rendered form (operators and `]` are single
    /// tokens in the LRA encoding).
    pub fn token_len(&self) -> usize {
        match self {
            Expr::Digit(_) => 1,
            Expr::Apply(_, args) => 2 + args.iter().map(Expr::token_len).sum::<usize>(),
        }
    }
}

/// Token vocabulary: 0 = PAD, 1-10 = digits 0-9, 11-16 = operators,
/// 17 = `]`. (vocab 18 ≤ the 20 reserved in the AOT configs.)
pub const PAD: i32 = 0;
pub const DIGIT_BASE: i32 = 1;
pub const OP_BASE: i32 = 11;
pub const CLOSE: i32 = 17;
pub const VOCAB: usize = 18;

/// Tokenize an expression tree.
pub fn tokenize(expr: &Expr, out: &mut Vec<i32>) {
    match expr {
        Expr::Digit(d) => out.push(DIGIT_BASE + *d as i32),
        Expr::Apply(op, args) => {
            let op_idx = OPERATORS.iter().position(|o| o == op).unwrap() as i32;
            out.push(OP_BASE + op_idx);
            for a in args {
                tokenize(a, out);
            }
            out.push(CLOSE);
        }
    }
}

/// Configurable generator.
#[derive(Clone, Debug)]
pub struct ListOpsGen {
    /// Maximum nesting depth (paper: ≤ 10).
    pub max_depth: usize,
    /// Arguments per operator node.
    pub min_args: usize,
    pub max_args: usize,
    /// Rejection-sample until the token length lands in this band
    /// (paper: 500–2000; our CPU-scaled default: 32–224).
    pub min_len: usize,
    pub max_len: usize,
    /// Probability that an argument recurses (vs being a digit); decays
    /// with depth to keep lengths controlled.
    pub branch_prob: f64,
}

impl Default for ListOpsGen {
    fn default() -> Self {
        Self {
            max_depth: 10,
            min_args: 2,
            max_args: 5,
            min_len: 32,
            max_len: 224,
            branch_prob: 0.35,
        }
    }
}

impl ListOpsGen {
    /// Paper-sized sequences (500–2000 tokens).
    pub fn paper_scale() -> Self {
        Self {
            min_len: 500,
            max_len: 2000,
            max_args: 8,
            ..Self::default()
        }
    }

    fn gen_expr(&self, rng: &mut Pcg64, depth: usize) -> Expr {
        if depth >= self.max_depth || (depth > 0 && !rng.bernoulli(self.branch_prob)) {
            return Expr::Digit(rng.next_below(10) as u8);
        }
        let op = *rng.choice(&OPERATORS);
        let n_args = rng.range_usize(self.min_args, self.max_args + 1);
        let args = (0..n_args).map(|_| self.gen_expr(rng, depth + 1)).collect();
        Expr::Apply(op, args)
    }

    /// Generate an expression whose token length is within the band.
    pub fn generate_expr(&self, rng: &mut Pcg64) -> Expr {
        loop {
            let mut expr = self.gen_expr(rng, 0);
            // Force a root operator (a bare digit is a degenerate task).
            if matches!(expr, Expr::Digit(_)) {
                expr = Expr::Apply(
                    *rng.choice(&OPERATORS),
                    vec![expr, Expr::Digit(rng.next_below(10) as u8)],
                );
            }
            let len = expr.token_len();
            if len >= self.min_len && len <= self.max_len {
                return expr;
            }
            // Too short: wrap in another operator layer to grow; too
            // long: resample (cheap — generation is microseconds).
            if len < self.min_len {
                let op = *rng.choice(&OPERATORS);
                let mut args = vec![expr];
                while args.len() < self.max_args {
                    args.push(self.gen_expr(rng, self.max_depth - 1));
                }
                let grown = Expr::Apply(op, args);
                if grown.token_len() <= self.max_len && grown.token_len() >= self.min_len {
                    return grown;
                }
            }
        }
    }
}

impl TaskGenerator for ListOpsGen {
    fn vocab(&self) -> usize {
        VOCAB
    }

    fn classes(&self) -> usize {
        10
    }

    fn generate(&self, rng: &mut Pcg64) -> Example {
        let expr = self.generate_expr(rng);
        let mut tokens = Vec::with_capacity(expr.token_len());
        tokenize(&expr, &mut tokens);
        Example {
            label: expr.eval() as i32,
            tokens,
        }
    }
}

/// Parse the canonical string form back into a tree (round-trip oracle
/// for tests; also lets users feed textual ListOps to the server).
pub fn parse(input: &str) -> Result<Expr, String> {
    let mut toks = input.split_whitespace().peekable();
    let expr = parse_tokens(&mut toks)?;
    if toks.next().is_some() {
        return Err("trailing tokens".into());
    }
    Ok(expr)
}

fn parse_tokens<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Expr, String> {
    match toks.next() {
        None => Err("unexpected end".into()),
        Some(t) if t.starts_with('[') => {
            let op = OPERATORS
                .iter()
                .find(|o| o.name() == t)
                .ok_or_else(|| format!("unknown operator {t}"))?;
            let mut args = Vec::new();
            loop {
                match toks.peek() {
                    Some(&"]") => {
                        toks.next();
                        break;
                    }
                    Some(_) => args.push(parse_tokens(toks)?),
                    None => return Err("missing ]".into()),
                }
            }
            if args.is_empty() {
                return Err("empty operator".into());
            }
            Ok(Expr::Apply(*op, args))
        }
        Some(d) => d
            .parse::<u8>()
            .ok()
            .filter(|&x| x < 10)
            .map(Expr::Digit)
            .ok_or_else(|| format!("bad digit {d}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{run, Config, Gen};

    #[test]
    fn operators_hand_checked() {
        assert_eq!(Op::Min.apply(&[3, 1, 4]), 1);
        assert_eq!(Op::Max.apply(&[3, 1, 4]), 4);
        assert_eq!(Op::Med.apply(&[3, 1, 4]), 3);
        assert_eq!(Op::Med.apply(&[4, 1, 3, 2]), 2); // lower median
        assert_eq!(Op::Sm.apply(&[7, 8]), 5);
        assert_eq!(Op::First.apply(&[9, 0, 1]), 9);
        assert_eq!(Op::Last.apply(&[9, 0, 1]), 1);
    }

    #[test]
    fn eval_nested_example() {
        // [MAX 2 9 [MIN 4 7 ] 0 ] = max(2, 9, min(4,7), 0) = 9
        let e = parse("[MAX 2 9 [MIN 4 7 ] 0 ]").unwrap();
        assert_eq!(e.eval(), 9);
        // [SM [MIN 8 6 ] [MAX 1 2 ] 9 ] = (6 + 2 + 9) % 10 = 7
        let e = parse("[SM [MIN 8 6 ] [MAX 1 2 ] 9 ]").unwrap();
        assert_eq!(e.eval(), 7);
    }

    #[test]
    fn render_parse_roundtrip() {
        let mut rng = Pcg64::new(1);
        let g = ListOpsGen::default();
        for _ in 0..50 {
            let e = g.generate_expr(&mut rng);
            let back = parse(&e.render()).unwrap();
            assert_eq!(e, back);
        }
    }

    #[test]
    fn token_len_matches_tokenize() {
        let mut rng = Pcg64::new(2);
        let g = ListOpsGen::default();
        for _ in 0..50 {
            let e = g.generate_expr(&mut rng);
            let mut toks = Vec::new();
            tokenize(&e, &mut toks);
            assert_eq!(toks.len(), e.token_len());
            assert!(toks.iter().all(|&t| (1..VOCAB as i32).contains(&t)));
        }
    }

    #[test]
    fn generated_lengths_in_band() {
        let mut rng = Pcg64::new(3);
        let g = ListOpsGen::default();
        for _ in 0..100 {
            let ex = g.generate(&mut rng);
            assert!(ex.tokens.len() >= g.min_len && ex.tokens.len() <= g.max_len);
            assert!((0..10).contains(&ex.label));
        }
    }

    #[test]
    fn labels_roughly_uniformish() {
        // Sanity: no single digit should dominate the label set.
        let mut rng = Pcg64::new(4);
        let g = ListOpsGen::default();
        let mut counts = [0usize; 10];
        for _ in 0..600 {
            counts[g.generate(&mut rng).label as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max < 300, "counts={counts:?}");
        assert!(counts.iter().all(|&c| c > 5), "counts={counts:?}");
    }

    #[test]
    fn prop_eval_bounded_and_min_le_max() {
        // Property: for any generated expr, MIN-wrapped eval <= MAX-wrapped.
        run(Config::default().cases(64), Gen::u64_range(0, u64::MAX / 2), |&seed| {
            let mut rng = Pcg64::new(seed);
            let g = ListOpsGen { min_len: 8, max_len: 64, ..Default::default() };
            let e = g.generate_expr(&mut rng);
            let v = e.eval();
            if v >= 10 {
                return false;
            }
            let wrapped_min = Expr::Apply(Op::Min, vec![e.clone(), Expr::Digit(5)]);
            let wrapped_max = Expr::Apply(Op::Max, vec![e, Expr::Digit(5)]);
            wrapped_min.eval() <= wrapped_max.eval()
        });
    }

    #[test]
    fn prop_first_last_consistency() {
        run(Config::default().cases(64), Gen::u64_range(0, u64::MAX / 2), |&seed| {
            let mut rng = Pcg64::new(seed);
            let g = ListOpsGen { min_len: 8, max_len: 64, ..Default::default() };
            let a = g.generate_expr(&mut rng);
            let b = g.generate_expr(&mut rng);
            let first = Expr::Apply(Op::First, vec![a.clone(), b.clone()]);
            let last = Expr::Apply(Op::Last, vec![a.clone(), b.clone()]);
            first.eval() == a.eval() && last.eval() == b.eval()
        });
    }

    #[test]
    fn paper_scale_band() {
        let mut rng = Pcg64::new(5);
        let g = ListOpsGen::paper_scale();
        let ex = g.generate(&mut rng);
        assert!(ex.tokens.len() >= 500 && ex.tokens.len() <= 2000);
    }
}

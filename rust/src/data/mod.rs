//! Data substrates (built from scratch — DESIGN.md §5 substitutions):
//!
//! * [`listops`] — a faithful Long ListOps generator + exact evaluator
//!   (the real LRA task, procedurally generated like the original);
//! * [`pixel`] — synthetic grayscale shape images serialized to pixel
//!   sequences (CIFAR10-pixel stand-in);
//! * [`textbytes`] — synthetic byte-level text classification
//!   (IMDB-Byte stand-in);
//! * [`batch`] — batch assembly, padding, and length bucketing shared
//!   by the trainer and the serving coordinator.

pub mod batch;
pub mod listops;
pub mod pixel;
pub mod textbytes;

/// A labelled token sequence (model-ready).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// Common interface for the three task generators.
pub trait TaskGenerator {
    /// Vocabulary size (token ids are `0..vocab`).
    fn vocab(&self) -> usize;
    /// Number of classes.
    fn classes(&self) -> usize;
    /// Generate one example with unpadded natural length.
    fn generate(&self, rng: &mut crate::util::rng::Pcg64) -> Example;
    /// Padding token id.
    fn pad_id(&self) -> i32 {
        0
    }
}

impl TaskGenerator for Box<dyn TaskGenerator> {
    fn vocab(&self) -> usize {
        (**self).vocab()
    }
    fn classes(&self) -> usize {
        (**self).classes()
    }
    fn generate(&self, rng: &mut crate::util::rng::Pcg64) -> Example {
        (**self).generate(rng)
    }
    fn pad_id(&self) -> i32 {
        (**self).pad_id()
    }
}

/// Generator for a named task, tuned to a model's sequence length
/// (matches the AOT config registry in `python/compile/aot.py`).
pub fn task_by_name(task: &str, seq_len: usize) -> Option<Box<dyn TaskGenerator>> {
    match task {
        "listops" => Some(Box::new(listops::ListOpsGen {
            min_len: 16,
            max_len: seq_len.saturating_sub(8).max(24),
            ..Default::default()
        })),
        "pixel" => Some(Box::new(pixel::PixelGen {
            side: (seq_len as f64).sqrt() as usize,
            ..Default::default()
        })),
        "textbytes" => Some(Box::new(textbytes::TextBytesGen {
            seq_len,
            ..Default::default()
        })),
        _ => None,
    }
}

#[cfg(test)]
mod factory_tests {
    use super::*;

    #[test]
    fn factory_produces_matching_generators() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        for (task, n, vocab, classes) in [
            ("listops", 256, 18, 10),
            ("pixel", 256, 256, 4),
            ("textbytes", 512, 256, 2),
        ] {
            let g = task_by_name(task, n).unwrap();
            assert_eq!(g.vocab(), vocab, "{task}");
            assert_eq!(g.classes(), classes, "{task}");
            let ex = g.generate(&mut rng);
            assert!(ex.tokens.len() <= n, "{task}: {} > {n}", ex.tokens.len());
            assert!((ex.label as usize) < classes);
        }
        assert!(task_by_name("nope", 128).is_none());
    }
}

//! Minimal dense tensor for the rust-side reference attention
//! implementations, tests, and host-side pre/post-processing.
//!
//! Row-major `f32` storage with an arbitrary-rank shape. This is *not*
//! a performance claim — the performant path runs through XLA — but the
//! matmul is cache-blocked so the pure-rust reference attention used in
//! tests and benches is not absurdly slow.

use crate::util::rng::Pcg64;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ---------- constructors ----------

    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], value: f32) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![value; shape.iter().product()],
        }
    }

    /// Standard-normal entries from a deterministic seed.
    pub fn randn(shape: &[usize], seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let data = (0..shape.iter().product())
            .map(|_| rng.next_gaussian() as f32)
            .collect();
        Self::new(shape, data)
    }

    /// Uniform entries in [lo, hi).
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let data = (0..shape.iter().product())
            .map(|_| lo + (hi - lo) * rng.next_f32())
            .collect();
        Self::new(shape, data)
    }

    /// Rows drawn uniformly from the unit sphere S^{d-1} — the sampling
    /// regime of the paper's Table 1 / Fig. 5 scaling study.
    pub fn rand_unit_rows(n: usize, d: usize, seed: u64) -> Self {
        let mut t = Self::randn(&[n, d], seed);
        for i in 0..n {
            let norm = (0..d).map(|j| t.at2(i, j).powi(2)).sum::<f32>().sqrt().max(1e-12);
            for j in 0..d {
                *t.at2_mut(i, j) /= norm;
            }
        }
        t
    }

    // ---------- shape ----------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?} mismatches",
            self.shape,
            shape
        );
        Tensor::new(shape, self.data.clone())
    }

    // ---------- element access (2-D helpers; hot in reference attn) ----------

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 2);
        &mut self.data[i * self.shape[1] + j]
    }

    /// Row view of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    // ---------- elementwise ----------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::new(&self.shape, self.data.iter().map(|&x| f(x)).collect())
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::new(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        )
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::new(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        )
    }

    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::new(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        )
    }

    // ---------- linear algebra ----------

    /// Cache-blocked matmul for 2-D tensors: `self (m×k) @ other (k×n)`.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2, "matmul lhs must be 2-D");
        assert_eq!(other.rank(), 2, "matmul rhs must be 2-D");
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Row-wise tensor product ⊠ from the paper (Section 3.2):
    /// `[A ⊠ B]_n = vec(A_n ⊗ B_n) ∈ R^{d_a·d_b}`.
    pub fn boxtimes(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        assert_eq!(self.shape[0], other.shape[0]);
        let (n, da) = (self.shape[0], self.shape[1]);
        let db = other.shape[1];
        let mut out = vec![0.0f32; n * da * db];
        for i in 0..n {
            let a = self.row(i);
            let b = other.row(i);
            let orow = &mut out[i * da * db..(i + 1) * da * db];
            for (p, &av) in a.iter().enumerate() {
                for (q, &bv) in b.iter().enumerate() {
                    orow[p * db + q] = av * bv;
                }
            }
        }
        Tensor::new(&[n, da * db], out)
    }

    /// Column sums of a 2-D tensor → 1-D of length `cols`
    /// (`Σ_col V` in the paper's constant-term computation).
    pub fn col_sums(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; n];
        for i in 0..m {
            for j in 0..n {
                out[j] += self.data[i * n + j];
            }
        }
        Tensor::new(&[n], out)
    }

    /// ℓ2-normalize every row, then scale by `scale` — the paper's
    /// q ← τ q / ‖q‖₂ input normalization.
    pub fn normalize_rows(&self, scale: f32) -> Tensor {
        assert_eq!(self.rank(), 2);
        let mut out = self.clone();
        let (m, n) = (self.shape[0], self.shape[1]);
        for i in 0..m {
            let norm = (0..n)
                .map(|j| out.at2(i, j).powi(2))
                .sum::<f32>()
                .sqrt()
                .max(1e-12);
            let f = scale / norm;
            for j in 0..n {
                *out.at2_mut(i, j) *= f;
            }
        }
        out
    }

    /// Concatenate along the last axis (2-D only).
    pub fn concat_cols(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        assert_eq!(self.shape[0], other.shape[0]);
        let (m, n1) = (self.shape[0], self.shape[1]);
        let n2 = other.shape[1];
        let mut out = vec![0.0f32; m * (n1 + n2)];
        for i in 0..m {
            out[i * (n1 + n2)..i * (n1 + n2) + n1].copy_from_slice(self.row(i));
            out[i * (n1 + n2) + n1..(i + 1) * (n1 + n2)].copy_from_slice(other.row(i));
        }
        Tensor::new(&[m, n1 + n2], out)
    }

    /// Split off the first `k` columns: returns `(left m×k, right m×(n-k))`.
    pub fn split_cols(&self, k: usize) -> (Tensor, Tensor) {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        assert!(k <= n);
        let mut left = vec![0.0f32; m * k];
        let mut right = vec![0.0f32; m * (n - k)];
        for i in 0..m {
            left[i * k..(i + 1) * k].copy_from_slice(&self.row(i)[..k]);
            right[i * (n - k)..(i + 1) * (n - k)].copy_from_slice(&self.row(i)[k..]);
        }
        (Tensor::new(&[m, k], left), Tensor::new(&[m, n - k], right))
    }

    // ---------- reductions / comparisons ----------

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm of the whole tensor.
    pub fn frobenius(&self) -> f64 {
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Mean ℓ2 norm of rows — the "mean size" statistic of Table 1.
    pub fn mean_row_norm(&self) -> f64 {
        assert_eq!(self.rank(), 2);
        let m = self.shape[0];
        (0..m)
            .map(|i| {
                self.row(i)
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / m as f64
    }

    /// Elementwise closeness à la `numpy.allclose`.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Largest absolute difference (diagnostics for test failures).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Index of the max element in a 1-D tensor (classification argmax).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::randn(&[5, 5], 1);
        let mut eye = Tensor::zeros(&[5, 5]);
        for i in 0..5 {
            *eye.at2_mut(i, i) = 1.0;
        }
        assert!(a.matmul(&eye).allclose(&a, 1e-6, 1e-6));
        assert!(eye.matmul(&a).allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::new(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_blocking_matches_naive_large() {
        // exercise the BK=64 blocking boundary
        let a = Tensor::randn(&[10, 130], 2);
        let b = Tensor::randn(&[130, 7], 3);
        let c = a.matmul(&b);
        // naive re-computation
        let mut expect = Tensor::zeros(&[10, 7]);
        for i in 0..10 {
            for j in 0..7 {
                let mut s = 0.0;
                for k in 0..130 {
                    s += a.at2(i, k) * b.at2(k, j);
                }
                *expect.at2_mut(i, j) = s;
            }
        }
        assert!(c.allclose(&expect, 1e-5, 1e-5));
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::randn(&[3, 7], 4);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().shape(), &[7, 3]);
        assert_eq!(a.at2(1, 5), a.transpose().at2(5, 1));
    }

    #[test]
    fn boxtimes_matches_definition() {
        // [A ⊠ B]_{n, p*db+q} = A_{n,p} B_{n,q}
        let a = Tensor::randn(&[4, 3], 5);
        let b = Tensor::randn(&[4, 2], 6);
        let c = a.boxtimes(&b);
        assert_eq!(c.shape(), &[4, 6]);
        for n in 0..4 {
            for p in 0..3 {
                for q in 0..2 {
                    let expect = a.at2(n, p) * b.at2(n, q);
                    assert!((c.at2(n, p * 2 + q) - expect).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn boxtimes_linearizes_squared_gram() {
        // Core identity of Section 3.2: (QKᵀ)⊙² = Q^⊠2 (K^⊠2)ᵀ.
        let q = Tensor::randn(&[6, 4], 7);
        let k = Tensor::randn(&[5, 4], 8);
        let gram = q.matmul(&k.transpose());
        let squared = gram.hadamard(&gram);
        let lin = q.boxtimes(&q).matmul(&k.boxtimes(&k).transpose());
        assert!(lin.allclose(&squared, 1e-4, 1e-4));
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let a = Tensor::randn(&[10, 8], 9);
        let n = a.normalize_rows(2.5);
        for i in 0..10 {
            let norm: f32 = n.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 2.5).abs() < 1e-4);
        }
    }

    #[test]
    fn rand_unit_rows_on_sphere() {
        let a = Tensor::rand_unit_rows(100, 16, 10);
        for i in 0..100 {
            let norm: f32 = a.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn concat_split_roundtrip() {
        let a = Tensor::randn(&[4, 3], 11);
        let b = Tensor::randn(&[4, 5], 12);
        let c = a.concat_cols(&b);
        assert_eq!(c.shape(), &[4, 8]);
        let (l, r) = c.split_cols(3);
        assert_eq!(l, a);
        assert_eq!(r, b);
    }

    #[test]
    fn col_sums() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.col_sums().data(), &[5.0, 7.0, 9.0]);
    }

    #[test]
    fn argmax_and_abs_max() {
        let a = Tensor::new(&[4], vec![0.1, -7.0, 3.0, 2.0]);
        assert_eq!(a.argmax(), 2);
        assert_eq!(a.abs_max(), 7.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0]);
    }
}

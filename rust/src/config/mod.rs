//! JSON-backed configuration for the binaries (server + trainer).

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::engine::EngineConfig;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::time::Duration;

/// Server configuration file schema:
///
/// ```json
/// {
///   "artifacts_dir": "artifacts",
///   "prefix": "serve",
///   "buckets": [128, 256, 512, 1024],
///   "batch_sizes": [1, 8],
///   "head_dim": 16,
///   "max_batch": 8,
///   "max_delay_ms": 5,
///   "queue_limit": 256,
///   "variant": "auto",
///   "n_layers": 2,
///   "d_ff": 128,
///   "layer_taus": [1.0, 1.2],
///   "model_seed": 42,
///   "spill_enabled": true,
///   "spill_dir": "/var/tmp/taylorshift-spill",
///   "spill_budget_mib": 256
/// }
/// ```
///
/// Streaming-model knobs (`n_layers`, `d_ff`, `layer_taus`,
/// `model_seed`) shape the whole-model decode path; a non-empty
/// `layer_taus` must have exactly `n_layers` entries. The `spill_*`
/// knobs control the disk spill tier for evicted decode sessions;
/// the parsed config goes through [`EngineConfig::validate`], so a
/// `spill_dir` with spill disabled or a zero byte budget is rejected
/// at load time.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub prefix: String,
    pub buckets: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub engine: EngineConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            prefix: "serve".into(),
            buckets: vec![128, 256, 512, 1024],
            batch_sizes: vec![1, 8],
            engine: EngineConfig::default(),
        }
    }
}

impl ServerConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(v) = j.get("artifacts_dir").and_then(|x| x.as_str()) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("prefix").and_then(|x| x.as_str()) {
            cfg.prefix = v.to_string();
        }
        if let Some(v) = j.get("buckets").and_then(|x| x.as_usize_vec()) {
            cfg.buckets = v;
        }
        if let Some(v) = j.get("batch_sizes").and_then(|x| x.as_usize_vec()) {
            cfg.batch_sizes = v;
        }
        let mut engine = EngineConfig {
            buckets: cfg.buckets.clone(),
            ..EngineConfig::default()
        };
        if let Some(v) = j.get("head_dim").and_then(|x| x.as_usize()) {
            engine.head_dim = v;
        }
        let mut policy = BatchPolicy::default();
        if let Some(v) = j.get("max_batch").and_then(|x| x.as_usize()) {
            policy.max_batch = v;
        }
        if let Some(v) = j.get("max_delay_ms").and_then(|x| x.as_f64()) {
            policy.max_delay = Duration::from_micros((v * 1000.0) as u64);
        }
        engine.policy = policy;
        if let Some(v) = j.get("queue_limit").and_then(|x| x.as_usize()) {
            engine.queue_limit = v;
        }
        if let Some(v) = j.get("variant").and_then(|x| x.as_str()) {
            engine.forced_variant = match v {
                "auto" => None,
                other => Some(
                    crate::attention::AttentionVariant::parse(other)
                        .ok_or_else(|| anyhow!("bad variant '{other}'"))?,
                ),
            };
        }
        // Streaming-decode knobs (all optional; see decode::DecodeConfig).
        if let Some(v) = j.get("decode_heads").and_then(|x| x.as_usize()) {
            engine.decode.heads = v;
        }
        if let Some(v) = j.get("decode_tau").and_then(|x| x.as_f64()) {
            engine.decode.tau = v as f32;
        }
        if let Some(v) = j.get("session_budget_mib").and_then(|x| x.as_f64()) {
            engine.decode.max_session_bytes = (v * 1024.0 * 1024.0) as u64;
        }
        if let Some(v) = j.get("max_sessions").and_then(|x| x.as_usize()) {
            engine.decode.max_sessions = v;
        }
        // Spill tier: persist evicted decode state to disk and restore
        // it on the next touch (see decode::SpillConfig).
        if let Some(v) = j.get("spill_enabled").and_then(|x| x.as_bool()) {
            engine.decode.spill.enabled = v;
        }
        if let Some(v) = j.get("spill_dir").and_then(|x| x.as_str()) {
            engine.decode.spill.dir = Some(std::path::PathBuf::from(v));
        }
        if let Some(v) = j.get("spill_budget_mib").and_then(|x| x.as_f64()) {
            engine.decode.spill.max_bytes = (v * 1024.0 * 1024.0) as u64;
        }
        // Streaming-model architecture (see model::ModelConfig).
        if let Some(v) = j.get("n_layers").and_then(|x| x.as_usize()) {
            engine.decode.n_layers = v;
        }
        if let Some(v) = j.get("d_ff").and_then(|x| x.as_usize()) {
            engine.decode.d_ff = v;
        }
        if let Some(arr) = j.get("layer_taus").and_then(|x| x.as_arr()) {
            engine.decode.layer_taus = arr
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|v| v as f32)
                        .ok_or_else(|| anyhow!("layer_taus entries must be numbers"))
                })
                .collect::<Result<Vec<f32>>>()?;
        }
        if let Some(v) = j.get("model_seed").and_then(|x| x.as_f64()) {
            engine.decode.model_seed = v as u64;
        }
        // Same invariants hand-built configs get from
        // `EngineConfig::builder()` — one validation path for both.
        engine.validate().map_err(|e| anyhow!("{e}"))?;
        cfg.engine = engine;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ServerConfig::default();
        assert_eq!(c.buckets, vec![128, 256, 512, 1024]);
        assert_eq!(c.engine.head_dim, 16);
    }

    #[test]
    fn parses_full_config() {
        let j = Json::parse(
            r#"{
                "artifacts_dir": "art",
                "prefix": "serve",
                "buckets": [64, 128],
                "batch_sizes": [1, 4],
                "head_dim": 32,
                "max_batch": 4,
                "max_delay_ms": 2.5,
                "queue_limit": 99,
                "variant": "efficient"
            }"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert_eq!(c.artifacts_dir, "art");
        assert_eq!(c.buckets, vec![64, 128]);
        assert_eq!(c.engine.buckets, vec![64, 128]);
        assert_eq!(c.engine.head_dim, 32);
        assert_eq!(c.engine.policy.max_batch, 4);
        assert_eq!(c.engine.policy.max_delay, Duration::from_micros(2500));
        assert_eq!(c.engine.queue_limit, 99);
        assert_eq!(
            c.engine.forced_variant,
            Some(crate::attention::AttentionVariant::Efficient)
        );
    }

    #[test]
    fn parses_decode_knobs() {
        let j = Json::parse(
            r#"{
                "decode_heads": 8,
                "decode_tau": 1.5,
                "session_budget_mib": 2.0,
                "max_sessions": 7
            }"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert_eq!(c.engine.decode.heads, 8);
        assert!((c.engine.decode.tau - 1.5).abs() < 1e-6);
        assert_eq!(c.engine.decode.max_session_bytes, 2 << 20);
        assert_eq!(c.engine.decode.max_sessions, 7);
    }

    #[test]
    fn parses_model_knobs() {
        let j = Json::parse(
            r#"{
                "n_layers": 3,
                "d_ff": 64,
                "layer_taus": [0.8, 1.0, 1.2],
                "model_seed": 7
            }"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert_eq!(c.engine.decode.n_layers, 3);
        assert_eq!(c.engine.decode.d_ff, 64);
        assert_eq!(c.engine.decode.layer_taus, vec![0.8, 1.0, 1.2]);
        assert_eq!(c.engine.decode.model_seed, 7);
    }

    #[test]
    fn layer_taus_length_must_match_layers() {
        let j = Json::parse(r#"{"n_layers": 2, "layer_taus": [1.0]}"#).unwrap();
        assert!(ServerConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"layer_taus": [1.0, "x"]}"#).unwrap();
        assert!(ServerConfig::from_json(&j).is_err(), "non-numeric tau rejected");
    }

    #[test]
    fn parses_spill_knobs() {
        let j = Json::parse(
            r#"{
                "spill_enabled": true,
                "spill_dir": "/tmp/ts-spill",
                "spill_budget_mib": 4.0
            }"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert!(c.engine.decode.spill.enabled);
        assert_eq!(
            c.engine.decode.spill.dir.as_deref(),
            Some(std::path::Path::new("/tmp/ts-spill"))
        );
        assert_eq!(c.engine.decode.spill.max_bytes, 4 << 20);
    }

    #[test]
    fn spill_dir_without_spill_rejected() {
        let j = Json::parse(r#"{"spill_dir": "/tmp/ts-spill"}"#).unwrap();
        let err = ServerConfig::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("spill"), "{err}");
        let j = Json::parse(r#"{"spill_enabled": true, "spill_budget_mib": 0}"#).unwrap();
        assert!(ServerConfig::from_json(&j).is_err(), "zero spill budget rejected");
    }

    #[test]
    fn auto_variant_is_none() {
        let j = Json::parse(r#"{"variant": "auto"}"#).unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert_eq!(c.engine.forced_variant, None);
    }

    #[test]
    fn bad_variant_errors() {
        let j = Json::parse(r#"{"variant": "warp"}"#).unwrap();
        assert!(ServerConfig::from_json(&j).is_err());
    }
}

//! In-tree testing support: a small property-based testing framework
//! (stand-in for `proptest`, which is unavailable offline).

pub mod prop;

//! Mini property-based testing framework.
//!
//! `proptest`-inspired but tiny: generators produce random values from a
//! seeded [`Pcg64`]; on failure the runner greedily **shrinks** the
//! counterexample via the generator's `shrink` candidates before
//! reporting. Deterministic per seed, so failures are reproducible by
//! rerunning the same test binary.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this environment)
//! use taylorshift::testing::prop::{Config, Gen, run};
//!
//! // Reversing twice is the identity.
//! run(Config::default().cases(64), Gen::vec(Gen::u64_range(0, 100), 0, 20), |xs| {
//!     let mut twice = xs.clone();
//!     twice.reverse();
//!     twice.reverse();
//!     twice == *xs
//! });
//! ```

use crate::util::rng::Pcg64;

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 128,
            seed: 0xDEC0DE,
            max_shrink_steps: 512,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A generator: sampling plus shrink candidates.
pub struct Gen<T> {
    sample: Box<dyn Fn(&mut Pcg64) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        sample: impl Fn(&mut Pcg64) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self {
            sample: Box::new(sample),
            shrink: Box::new(shrink),
        }
    }

    /// Constant generator (no shrinking).
    pub fn just(value: T) -> Self {
        let v2 = value.clone();
        Gen::new(move |_| value.clone(), move |_| vec![])
            .with_shrink(move |_| vec![v2.clone()])
    }

    fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Self {
        self.shrink = Box::new(shrink);
        self
    }

    pub fn sample(&self, rng: &mut Pcg64) -> T {
        (self.sample)(rng)
    }

    pub fn shrink_candidates(&self, value: &T) -> Vec<T> {
        (self.shrink)(value)
    }

    /// Map the generated value (shrinking disabled across the map; use
    /// sparingly for derived values).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U> {
        let sample = self.sample;
        Gen::new(move |rng| f((sample)(rng)), |_| vec![])
    }
}

impl Gen<u64> {
    /// Uniform in the inclusive range; shrinks toward `lo`.
    pub fn u64_range(lo: u64, hi: u64) -> Gen<u64> {
        Gen::new(
            move |rng| rng.range_u64(lo, hi),
            move |&v| {
                let mut c = Vec::new();
                if v > lo {
                    c.push(lo);
                    c.push(lo + (v - lo) / 2);
                    c.push(v - 1);
                }
                c.dedup();
                c
            },
        )
    }
}

impl Gen<usize> {
    /// Uniform usize range; shrinks toward `lo`.
    pub fn usize_range(lo: usize, hi: usize) -> Gen<usize> {
        Gen::new(
            move |rng| rng.range_u64(lo as u64, hi as u64) as usize,
            move |&v| {
                let mut c = Vec::new();
                if v > lo {
                    c.push(lo);
                    c.push(lo + (v - lo) / 2);
                    c.push(v - 1);
                }
                c.dedup();
                c
            },
        )
    }
}

impl Gen<f64> {
    /// Uniform in [lo, hi); shrinks toward 0 / lo.
    pub fn f64_range(lo: f64, hi: f64) -> Gen<f64> {
        Gen::new(
            move |rng| lo + (hi - lo) * rng.next_f64(),
            move |&v| {
                let mut c = vec![];
                if v != lo {
                    c.push(lo);
                }
                if lo <= 0.0 && 0.0 <= hi && v != 0.0 {
                    c.push(0.0);
                }
                c.push(v / 2.0);
                c
            },
        )
    }
}

impl<T: Clone + 'static> Gen<Vec<T>> {
    /// Vector with length in [min_len, max_len]; shrinks by halving
    /// length, dropping elements, and shrinking single elements.
    pub fn vec(element: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
        let element = std::rc::Rc::new(element);
        let e1 = element.clone();
        Gen::new(
            move |rng| {
                let len = rng.range_u64(min_len as u64, max_len as u64) as usize;
                (0..len).map(|_| e1.sample(rng)).collect()
            },
            move |v: &Vec<T>| {
                let mut out: Vec<Vec<T>> = Vec::new();
                if v.len() > min_len {
                    // halve
                    out.push(v[..min_len.max(v.len() / 2)].to_vec());
                    // drop one element at a few positions
                    for i in [0, v.len() / 2, v.len() - 1] {
                        if v.len() - 1 >= min_len {
                            let mut w = v.clone();
                            w.remove(i.min(w.len() - 1));
                            out.push(w);
                        }
                    }
                }
                // shrink a single element
                for (i, x) in v.iter().enumerate().take(4) {
                    for cand in element.shrink_candidates(x) {
                        let mut w = v.clone();
                        w[i] = cand;
                        out.push(w);
                    }
                }
                out
            },
        )
    }
}

/// Pair two generators.
pub fn pair<A: Clone + 'static, B: Clone + 'static>(ga: Gen<A>, gb: Gen<B>) -> Gen<(A, B)> {
    let ga = std::rc::Rc::new(ga);
    let gb = std::rc::Rc::new(gb);
    let (ga1, gb1) = (ga.clone(), gb.clone());
    Gen::new(
        move |rng| (ga1.sample(rng), gb1.sample(rng)),
        move |(a, b)| {
            let mut out = Vec::new();
            for ca in ga.shrink_candidates(a) {
                out.push((ca, b.clone()));
            }
            for cb in gb.shrink_candidates(b) {
                out.push((a.clone(), cb));
            }
            out
        },
    )
}

/// Run `property` on `config.cases` random inputs; panics with the
/// (shrunk) counterexample on the first failure.
pub fn run<T: Clone + std::fmt::Debug + 'static>(
    config: Config,
    gen: Gen<T>,
    property: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg64::new(config.seed);
    for case in 0..config.cases {
        let input = gen.sample(&mut rng);
        if !check(&property, &input) {
            let shrunk = shrink_loop(&gen, &property, input.clone(), config.max_shrink_steps);
            panic!(
                "property failed (case {case}, seed {:#x}).\n  original: {:?}\n  shrunk:   {:?}",
                config.seed, input, shrunk
            );
        }
    }
}

fn check<T>(property: &impl Fn(&T) -> bool, input: &T) -> bool {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(input))).unwrap_or(false)
}

fn shrink_loop<T: Clone + 'static>(
    gen: &Gen<T>,
    property: &impl Fn(&T) -> bool,
    mut failing: T,
    max_steps: usize,
) -> T {
    let mut steps = 0;
    'outer: while steps < max_steps {
        for cand in gen.shrink_candidates(&failing) {
            steps += 1;
            if !check(property, &cand) {
                failing = cand;
                continue 'outer;
            }
            if steps >= max_steps {
                break 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run(Config::default().cases(64), Gen::u64_range(0, 1000), |&x| {
            x <= 1000
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        // Property "x < 50" fails for x >= 50; minimal counterexample
        // reachable by our shrinker from any failing x is 50.
        let result = std::panic::catch_unwind(|| {
            run(Config::default().cases(256), Gen::u64_range(0, 1000), |&x| {
                x < 50
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk:   50"), "msg: {msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        run(
            Config::default().cases(128),
            Gen::vec(Gen::u64_range(0, 9), 2, 17),
            |xs| xs.len() >= 2 && xs.len() <= 17 && xs.iter().all(|&x| x <= 9),
        );
    }

    #[test]
    fn vec_shrinks_toward_short() {
        // "no vector contains a 7" — shrunk failure should be short.
        let result = std::panic::catch_unwind(|| {
            run(
                Config::default().cases(512),
                Gen::vec(Gen::u64_range(0, 9), 0, 30),
                |xs| !xs.contains(&7),
            );
        });
        assert!(result.is_err());
    }

    #[test]
    fn pair_generator() {
        run(
            Config::default().cases(64),
            pair(Gen::usize_range(1, 64), Gen::usize_range(1, 8)),
            |&(n, d)| n >= 1 && d <= 8,
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Pcg64::new(99);
        let mut r2 = Pcg64::new(99);
        let g = Gen::u64_range(0, 1 << 40);
        for _ in 0..32 {
            assert_eq!(g.sample(&mut r1), g.sample(&mut r2));
        }
    }

    #[test]
    fn panicking_property_counts_as_failure() {
        let result = std::panic::catch_unwind(|| {
            run(Config::default().cases(16), Gen::u64_range(0, 10), |&x| {
                if x > 2 {
                    panic!("boom");
                }
                true
            });
        });
        assert!(result.is_err());
    }
}

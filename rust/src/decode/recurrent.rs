//! Recurrent form of efficient-TaylorShift for autoregressive decode.
//!
//! Algorithm 1 evaluates `Ŷ = ½ Q^⊠2 ((K^⊠2)ᵀ V̂) + α² Q (Kᵀ V̂) + α⁴ Σᵢ V̂ᵢ`
//! where every K/V term is a *sum over prefix positions* — so, as in
//! linear-attention RNNs (Katharopoulos et al.), the three moments
//!
//! ```text
//! M₀ = Σⱼ uⱼ             ∈ R^{d+1}        (uⱼ = [1 | vⱼ], unscaled)
//! M₁ = Σⱼ k̂ⱼ ⊗ uⱼ        ∈ R^{d×(d+1)}    (k̂ = α·k/‖k‖)
//! M₂ = Σⱼ (k̂ⱼ ⊠ k̂ⱼ) ⊗ uⱼ ∈ R^{d²×(d+1)}
//! ```
//!
//! are a sufficient statistic for the whole prefix: appending a token
//! is a rank-1 update in O(d²(d+1)), and a query contracts the moments
//! in O(d²(d+1)) — both independent of the prefix length N. The 1/N
//! and √(d/N) factors that Algorithm 1 folds into V̂ cancel in the
//! final nominator/denominator ratio, leaving a closed-form √(N/d)
//! output rescale; keeping the moments unscaled is what makes the
//! update O(1) per token (no N-dependent rescaling of state).
//!
//! Accumulators are f64 so that very long prefixes (N ≫ 10⁵) do not
//! lose the parity-with-recompute guarantee to summation error.

use crate::analysis::memory;
use crate::util::bytes::{ByteReader, ByteWriter, CodecError};
use crate::util::numeric::guard_denom;

/// Running-moment state for one attention head on the efficient branch.
#[derive(Clone, Debug)]
pub struct RecurrentState {
    d: usize,
    len: usize,
    alpha: f64,
    tau: f64,
    /// Σⱼ uⱼ, length d+1.
    m0: Vec<f64>,
    /// Σⱼ k̂ⱼ ⊗ uⱼ, row-major d × (d+1).
    m1: Vec<f64>,
    /// Σⱼ (k̂ⱼ ⊠ k̂ⱼ) ⊗ uⱼ, row-major d² × (d+1).
    m2: Vec<f64>,
}

impl RecurrentState {
    pub fn new(d: usize, tau: f32) -> Self {
        assert!(d > 0, "head dim must be positive");
        let w = d + 1;
        Self {
            d,
            len: 0,
            alpha: (d as f64).powf(0.25),
            tau: tau as f64,
            m0: vec![0.0; w],
            m1: vec![0.0; d * w],
            m2: vec![0.0; d * d * w],
        }
    }

    /// Tokens absorbed so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn head_dim(&self) -> usize {
        self.d
    }

    pub fn tau(&self) -> f32 {
        self.tau as f32
    }

    /// Bytes held by the moment accumulators (f64 entries, length-free).
    pub fn state_bytes(&self) -> u64 {
        memory::bytes(memory::entries_decode_recurrent(self.d as u64), 8)
    }

    /// Absorb one (k, v) token in O(d³), independent of the prefix.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d, "key dim mismatch");
        assert_eq!(v.len(), self.d, "value dim mismatch");
        let (d, w) = (self.d, self.d + 1);
        // Same ‖k‖ guard as Tensor::normalize_rows.
        let norm = k.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let scale = self.alpha / norm.max(1e-12);
        let kn: Vec<f64> = k.iter().map(|&x| x as f64 * scale).collect();
        let mut u = vec![0.0f64; w];
        u[0] = 1.0;
        for (c, &x) in v.iter().enumerate() {
            u[c + 1] = x as f64;
        }
        for c in 0..w {
            self.m0[c] += u[c];
        }
        for a in 0..d {
            let ka = kn[a];
            let row1 = &mut self.m1[a * w..(a + 1) * w];
            for c in 0..w {
                row1[c] += ka * u[c];
            }
            for b in 0..d {
                let kab = ka * kn[b];
                let row2 = &mut self.m2[(a * d + b) * w..(a * d + b + 1) * w];
                for c in 0..w {
                    row2[c] += kab * u[c];
                }
            }
        }
        self.len += 1;
    }

    /// Attention output of `q` over the absorbed prefix: equals the last
    /// row of `taylor_efficient` run on the full prefix, in O(d³).
    pub fn query(&self, q: &[f32]) -> Vec<f32> {
        assert_eq!(q.len(), self.d, "query dim mismatch");
        assert!(self.len > 0, "query over empty prefix");
        let (d, w) = (self.d, self.d + 1);
        let norm = q.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let scale = self.alpha * self.tau / norm.max(1e-12);
        let qn: Vec<f64> = q.iter().map(|&x| x as f64 * scale).collect();
        let a2 = self.alpha * self.alpha;
        let a4 = a2 * a2;
        let mut y = vec![0.0f64; w];
        for (c, out) in y.iter_mut().enumerate() {
            *out = a4 * self.m0[c];
        }
        for a in 0..d {
            let qa = qn[a];
            let row1 = &self.m1[a * w..(a + 1) * w];
            for (c, out) in y.iter_mut().enumerate() {
                *out += a2 * qa * row1[c];
            }
            for b in 0..d {
                let coef = 0.5 * qa * qn[b];
                let row2 = &self.m2[(a * d + b) * w..(a * d + b + 1) * w];
                for (c, out) in y.iter_mut().enumerate() {
                    *out += coef * row2[c];
                }
            }
        }
        // Per-token Taylor weights are ½(s+1)²+½ > 0 (scaled by α⁴), so
        // the denominator is ≥ α⁴ in exact arithmetic and the guard is
        // a numerical no-op — kept so release builds cannot divide by
        // zero on degenerate state (mirrored in `causal.rs`).
        let denom = guard_denom(y[0]);
        let rescale = (self.len as f64 / d as f64).sqrt();
        (0..d).map(|c| (y[c + 1] / denom * rescale) as f32).collect()
    }

    /// The per-token decode step: absorb (k, v), then attend with `q`
    /// (causal self-attention includes the new token itself).
    pub fn decode_step(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        self.append(k, v);
        self.query(q)
    }

    /// Serialize the moment accumulators bit-exactly (spill path).
    /// The f64 moments ARE the parity guarantee for long prefixes, so
    /// they go to disk as raw bit patterns, never rounded through f32.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.put_u32(self.d as u32);
        w.put_u64(self.len as u64);
        w.put_f64(self.alpha);
        w.put_f64(self.tau);
        w.put_f64_slice(&self.m0);
        w.put_f64_slice(&self.m1);
        w.put_f64_slice(&self.m2);
    }

    /// Inverse of [`RecurrentState::encode`]; validates the moment
    /// shapes against the head dim before accepting the state.
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self, CodecError> {
        let d = r.get_u32()? as usize;
        if d == 0 || d > 1 << 12 {
            return Err(CodecError::Invalid { what: "recurrent head dim" });
        }
        let len = r.get_u64()? as usize;
        let alpha = r.get_f64()?;
        let tau = r.get_f64()?;
        let w = d + 1;
        let m0 = r.get_f64_vec(w)?;
        let m1 = r.get_f64_vec(d * w)?;
        let m2 = r.get_f64_vec(d * d * w)?;
        if m0.len() != w || m1.len() != d * w || m2.len() != d * d * w {
            return Err(CodecError::Invalid { what: "moment shapes" });
        }
        Ok(Self { d, len, alpha, tau, m0, m1, m2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::efficient::taylor_efficient;
    use crate::tensor::Tensor;

    #[test]
    fn matches_full_recompute_every_step() {
        let (n, d, tau) = (48usize, 8usize, 1.3f32);
        let q = Tensor::randn(&[n, d], 10);
        let k = Tensor::randn(&[n, d], 11);
        let v = Tensor::randn(&[n, d], 12);
        let mut state = RecurrentState::new(d, tau);
        for t in 0..n {
            let y = state.decode_step(q.row(t), k.row(t), v.row(t));
            let prefix = t + 1;
            let qp = Tensor::new(&[prefix, d], q.data()[..prefix * d].to_vec());
            let kp = Tensor::new(&[prefix, d], k.data()[..prefix * d].to_vec());
            let vp = Tensor::new(&[prefix, d], v.data()[..prefix * d].to_vec());
            let want = taylor_efficient(&qp, &kp, &vp, tau);
            let diff: f32 = y
                .iter()
                .zip(want.row(t))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff < 1e-4, "step {t}: max abs diff {diff}");
        }
    }

    #[test]
    fn state_size_is_length_independent() {
        let mut state = RecurrentState::new(16, 1.0);
        let bytes0 = state.state_bytes();
        let k = vec![0.5f32; 16];
        let v = vec![0.25f32; 16];
        for _ in 0..100 {
            state.append(&k, &v);
        }
        assert_eq!(state.len(), 100);
        assert_eq!(state.state_bytes(), bytes0);
        // (d+1)(d²+d+1) f64 entries.
        assert_eq!(bytes0, 17 * (256 + 16 + 1) * 8);
    }

    #[test]
    fn encode_decode_roundtrip_is_bit_exact() {
        let (n, d, tau) = (37usize, 6usize, 1.1f32);
        let q = Tensor::randn(&[n, d], 40);
        let k = Tensor::randn(&[n, d], 41);
        let v = Tensor::randn(&[n, d], 42);
        let mut state = RecurrentState::new(d, tau);
        for t in 0..n {
            state.append(k.row(t), v.row(t));
        }
        let mut w = crate::util::bytes::ByteWriter::new();
        state.encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bytes::ByteReader::new(&bytes);
        let back = RecurrentState::decode(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.len(), state.len());
        // Moments are f64 accumulators — the round trip must preserve
        // every bit, and therefore every future query result.
        let a = state.query(q.row(n - 1));
        let b = back.query(q.row(n - 1));
        let eq = a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "restored query must be bit-exact");
    }

    #[test]
    fn decode_rejects_truncated_moments() {
        let mut state = RecurrentState::new(4, 1.0);
        state.append(&[1.0; 4], &[2.0; 4]);
        let mut w = crate::util::bytes::ByteWriter::new();
        state.encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bytes::ByteReader::new(&bytes[..bytes.len() - 9]);
        assert!(RecurrentState::decode(&mut r).is_err());
    }

    #[test]
    #[should_panic(expected = "query over empty prefix")]
    fn query_on_empty_prefix_panics() {
        let state = RecurrentState::new(4, 1.0);
        state.query(&[1.0, 0.0, 0.0, 0.0]);
    }
}

//! Streaming autoregressive decode with crossover-aware cached state.
//!
//! One-shot inference picks between direct- and efficient-TaylorShift
//! per request (`attention/selector.rs`). At decode time the same
//! crossover governs *what state to cache per layer, per session*:
//!
//! * **Below N₀(d)** — the direct branch with a [`KvCache`]: keep the
//!   normalized keys and raw values, O(N·d) state, O(N·d) per token.
//! * **Above N₀(d)** — the efficient branch admits a recurrent form
//!   ([`RecurrentState`]): three fixed-size moment accumulators over
//!   the prefix, O(d³) state, O(d³) per token — flat in N.
//!
//! A [`DecodeSession`] starts on the KV path and is **promoted** to
//! recurrent state the step its length crosses the selector threshold
//! (a one-time O(N·d³) replay of the cache). Both branches compute the
//! same attention function, so the emitted token stream is continuous
//! across the switch — the "(and Back)" policy applied while decoding.
//! The promotion invariants (what the replay covers, how the promoting
//! token is absorbed, and the batch-side mirror that makes
//! streaming-vs-batch parity exact) are spelled out in
//! `attention/causal.rs` and `model/`.
//!
//! This module owns the *per-layer* state machinery. Whole-model
//! streaming — a stack of these sessions, one per transformer block,
//! each crossing N₀(d) independently — lives in [`crate::model`]:
//! [`crate::model::ModelSession`] is the per-layer stack and
//! [`crate::model::SessionStore`] keeps many of them resident under a
//! byte budget (summed across layers) with LRU eviction. The serving
//! integration lives in `coordinator/engine.rs` (`submit_stream` /
//! `decode_step` / `close_stream`), which mixes decode steps with
//! batched prefill through a priority lane in `coordinator/batcher.rs`
//! and reports occupancy, promotions, evictions, and per-token latency
//! through `coordinator/metrics.rs`.

pub mod kv;
pub mod recurrent;
pub mod session;

pub use kv::KvCache;
pub use recurrent::RecurrentState;
pub use session::{DecodeConfig, DecodeSession, SpillConfig, StepResult};

//! KV-cache decode path for the direct-TaylorShift branch.
//!
//! Below the crossover N₀(d) the direct branch is the faster choice,
//! and at decode time it behaves like vanilla attention with a KV
//! cache: keep the (normalized) keys and raw values of the prefix and
//! re-score them against each new query — O(N·d) per token, O(N·d)
//! state. Keys are stored ℓ2-normalized (normalization is idempotent,
//! which keeps the later KV→recurrent promotion exact); values are
//! stored raw.

use crate::analysis::memory;
use crate::util::bytes::{ByteReader, ByteWriter, CodecError};
use crate::util::numeric::guard_denom;

/// Upper bound on decoded slice lengths: spill files are written by
/// this process, so anything past ~1 GiB of entries is corruption.
const MAX_DECODE_ENTRIES: usize = 1 << 28;

/// Cached prefix for one attention head on the direct branch.
#[derive(Clone, Debug)]
pub struct KvCache {
    d: usize,
    tau: f64,
    /// ℓ2-normalized key rows, row-major len × d.
    keys: Vec<f32>,
    /// Raw value rows, row-major len × d.
    values: Vec<f32>,
}

impl KvCache {
    pub fn new(d: usize, tau: f32) -> Self {
        assert!(d > 0, "head dim must be positive");
        Self {
            d,
            tau: tau as f64,
            keys: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Tokens cached so far.
    pub fn len(&self) -> usize {
        self.keys.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn head_dim(&self) -> usize {
        self.d
    }

    pub fn tau(&self) -> f32 {
        self.tau as f32
    }

    /// Bytes held by the cached keys and values (f32 entries).
    pub fn state_bytes(&self) -> u64 {
        memory::bytes(
            memory::entries_decode_kv(self.len() as u64, self.d as u64),
            4,
        )
    }

    /// Normalized key row `i` (for promotion rebuilds).
    pub fn key_row(&self, i: usize) -> &[f32] {
        &self.keys[i * self.d..(i + 1) * self.d]
    }

    /// Raw value row `i`.
    pub fn value_row(&self, i: usize) -> &[f32] {
        &self.values[i * self.d..(i + 1) * self.d]
    }

    /// Cache one (k, v) token in O(d).
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d, "key dim mismatch");
        assert_eq!(v.len(), self.d, "value dim mismatch");
        let norm = k.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let scale = (1.0 / norm.max(1e-12)) as f32;
        self.keys.extend(k.iter().map(|&x| x * scale));
        self.values.extend_from_slice(v);
    }

    /// Attention output of `q` over the cached prefix: equals the last
    /// row of `taylor_direct(…, tau, true)` on the full prefix, in
    /// O(N·d).
    pub fn query(&self, q: &[f32]) -> Vec<f32> {
        assert_eq!(q.len(), self.d, "query dim mismatch");
        let n = self.len();
        assert!(n > 0, "query over empty prefix");
        let d = self.d;
        let norm = q.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let scale = self.tau / norm.max(1e-12);
        let qn: Vec<f64> = q.iter().map(|&x| x as f64 * scale).collect();
        let mut num = vec![0.0f64; d];
        let mut den = 0.0f64;
        for j in 0..n {
            let key = self.key_row(j);
            let mut s = 0.0f64;
            for c in 0..d {
                s += qn[c] * key[c] as f64;
            }
            // w = 1 + s + s²/2 = ½(s+1)² + ½ > 0, so no |·| needed.
            let w = 1.0 + s + 0.5 * s * s;
            den += w;
            let val = self.value_row(j);
            for c in 0..d {
                num[c] += w * val[c] as f64;
            }
        }
        let rescale = (n as f64 / d as f64).sqrt() / guard_denom(den);
        num.iter().map(|&x| (x * rescale) as f32).collect()
    }

    /// The per-token decode step: cache (k, v), then attend with `q`.
    pub fn decode_step(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        self.append(k, v);
        self.query(q)
    }

    /// Serialize the cache bit-exactly (spill path). Keys are already
    /// ℓ2-normalized in storage, so the round trip reproduces the
    /// exact in-memory bits — no re-normalization on restore.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.put_u32(self.d as u32);
        w.put_f64(self.tau);
        w.put_f32_slice(&self.keys);
        w.put_f32_slice(&self.values);
    }

    /// Inverse of [`KvCache::encode`]; validates structure but trusts
    /// the float bits (the spill layer checksums the whole payload).
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self, CodecError> {
        let d = r.get_u32()? as usize;
        if d == 0 {
            return Err(CodecError::Invalid { what: "kv head dim" });
        }
        let tau = r.get_f64()?;
        let keys = r.get_f32_vec(MAX_DECODE_ENTRIES)?;
        let values = r.get_f32_vec(MAX_DECODE_ENTRIES)?;
        if keys.len() != values.len() || keys.len() % d != 0 {
            return Err(CodecError::Invalid { what: "kv row shape" });
        }
        Ok(Self { d, tau, keys, values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::direct::taylor_direct;
    use crate::tensor::Tensor;

    #[test]
    fn matches_full_recompute_every_step() {
        let (n, d, tau) = (40usize, 6usize, 0.8f32);
        let q = Tensor::randn(&[n, d], 20);
        let k = Tensor::randn(&[n, d], 21);
        let v = Tensor::randn(&[n, d], 22);
        let mut cache = KvCache::new(d, tau);
        for t in 0..n {
            let y = cache.decode_step(q.row(t), k.row(t), v.row(t));
            let prefix = t + 1;
            let qp = Tensor::new(&[prefix, d], q.data()[..prefix * d].to_vec());
            let kp = Tensor::new(&[prefix, d], k.data()[..prefix * d].to_vec());
            let vp = Tensor::new(&[prefix, d], v.data()[..prefix * d].to_vec());
            let want = taylor_direct(&qp, &kp, &vp, tau, true);
            let diff: f32 = y
                .iter()
                .zip(want.row(t))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff < 1e-4, "step {t}: max abs diff {diff}");
        }
    }

    #[test]
    fn state_grows_linearly() {
        let d = 16usize;
        let mut cache = KvCache::new(d, 1.0);
        let k = vec![1.0f32; d];
        let v = vec![2.0f32; d];
        for t in 1..=10 {
            cache.append(&k, &v);
            assert_eq!(cache.state_bytes(), (2 * t * d * 4) as u64);
        }
    }

    #[test]
    fn encode_decode_roundtrip_is_bit_exact() {
        let (n, d, tau) = (13usize, 5usize, 0.7f32);
        let q = Tensor::randn(&[n, d], 30);
        let k = Tensor::randn(&[n, d], 31);
        let v = Tensor::randn(&[n, d], 32);
        let mut cache = KvCache::new(d, tau);
        for t in 0..n {
            cache.append(k.row(t), v.row(t));
        }
        let mut w = crate::util::bytes::ByteWriter::new();
        cache.encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bytes::ByteReader::new(&bytes);
        let back = KvCache::decode(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.len(), cache.len());
        let a = cache.query(q.row(n - 1));
        let b = back.query(q.row(n - 1));
        let eq = a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "restored query must be bit-exact");
    }

    #[test]
    fn decode_rejects_row_shape_mismatch() {
        let mut cache = KvCache::new(4, 1.0);
        cache.append(&[1.0; 4], &[2.0; 4]);
        let mut w = crate::util::bytes::ByteWriter::new();
        cache.encode(&mut w);
        let mut bytes = w.into_bytes();
        // Corrupt the head dim so rows no longer divide evenly.
        bytes[0] = 3;
        let mut r = crate::util::bytes::ByteReader::new(&bytes);
        assert!(KvCache::decode(&mut r).is_err());
    }

    #[test]
    fn stored_keys_are_unit_norm() {
        let d = 8usize;
        let mut cache = KvCache::new(d, 1.0);
        cache.append(&vec![3.0f32; d], &vec![1.0f32; d]);
        let norm: f32 = cache.key_row(0).iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }
}

//! Per-session decode state and the LRU session store.
//!
//! A [`DecodeSession`] holds one multi-head streaming context. It
//! starts on the branch the [`Selector`] picks for a length-1 prefix
//! (direct/KV below the crossover) and is *promoted* to the recurrent
//! moment state when its length crosses N₀(d) — the paper's "(and
//! Back)" switch applied at decode time. Promotion replays the cached
//! (k, v) pairs into [`RecurrentState`] once (O(N·d³)); because the
//! two branches compute the same function, the output stream is
//! continuous across the switch.
//!
//! The [`SessionStore`] keeps many sessions resident under a byte
//! budget, accounted through `analysis/memory.rs` entry counts, and
//! evicts least-recently-used sessions when the budget (or a session
//! count cap) is exceeded.

use std::collections::HashMap;

use super::kv::KvCache;
use super::recurrent::RecurrentState;
use crate::attention::selector::Selector;
use crate::attention::AttentionVariant;
use crate::tensor::Tensor;

/// Decode-subsystem configuration (engine-level).
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeConfig {
    /// Attention heads per streaming session.
    pub heads: usize,
    /// Temperature shared by both branches.
    pub tau: f32,
    /// Total resident-state budget across sessions, in bytes.
    pub max_session_bytes: u64,
    /// Hard cap on resident sessions regardless of bytes.
    pub max_sessions: usize,
    /// Max decode steps the engine serves ahead of due prefill batches
    /// in one drive cycle (the decode/prefill mixing knob).
    pub max_steps_per_cycle: usize,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        Self {
            heads: 4,
            tau: 1.0,
            max_session_bytes: 64 << 20,
            max_sessions: 256,
            max_steps_per_cycle: 64,
        }
    }
}

enum Branch {
    Kv(Vec<KvCache>),
    Recurrent(Vec<RecurrentState>),
}

/// Result of one decode step.
#[derive(Clone, Debug)]
pub struct StepResult {
    /// Concatenated per-head outputs, length `heads · d`.
    pub output: Vec<f32>,
    /// Branch that produced this step.
    pub branch: AttentionVariant,
    /// True iff this step triggered the KV→recurrent promotion.
    pub promoted: bool,
    /// Prefix length after this step.
    pub len: usize,
}

/// One multi-head streaming decode context.
pub struct DecodeSession {
    heads: usize,
    d: usize,
    len: usize,
    branch: Branch,
    promoted_at: Option<usize>,
    bytes: u64,
    last_used: u64,
}

impl DecodeSession {
    /// A fresh session. `start_recurrent` skips the KV phase entirely
    /// (used when the variant is forced to Efficient).
    pub fn new(heads: usize, d: usize, tau: f32, start_recurrent: bool) -> Self {
        assert!(heads > 0 && d > 0, "heads and head dim must be positive");
        let branch = if start_recurrent {
            Branch::Recurrent((0..heads).map(|_| RecurrentState::new(d, tau)).collect())
        } else {
            Branch::Kv((0..heads).map(|_| KvCache::new(d, tau)).collect())
        };
        let mut s = Self {
            heads,
            d,
            len: 0,
            branch,
            promoted_at: None,
            bytes: 0,
            last_used: 0,
        };
        s.bytes = s.state_bytes();
        s
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.d
    }

    /// Variant currently serving this session.
    pub fn branch(&self) -> AttentionVariant {
        match self.branch {
            Branch::Kv(_) => AttentionVariant::Direct,
            Branch::Recurrent(_) => AttentionVariant::Efficient,
        }
    }

    /// Prefix length at which the session switched to recurrent state.
    pub fn promoted_at(&self) -> Option<usize> {
        self.promoted_at
    }

    /// Resident bytes of this session's state.
    pub fn state_bytes(&self) -> u64 {
        match &self.branch {
            Branch::Kv(caches) => caches.iter().map(KvCache::state_bytes).sum(),
            Branch::Recurrent(states) => states.iter().map(RecurrentState::state_bytes).sum(),
        }
    }

    /// Switch KV → recurrent by replaying the cached prefix into the
    /// moment accumulators (one-time O(N·d³)). No-op if already
    /// recurrent. Exact: the cached keys are already normalized and
    /// both branches compute the same attention function.
    pub fn promote(&mut self) -> bool {
        let Branch::Kv(caches) = &self.branch else {
            return false;
        };
        let states: Vec<RecurrentState> = caches
            .iter()
            .map(|cache| {
                let mut state = RecurrentState::new(self.d, cache.tau());
                for i in 0..cache.len() {
                    state.append(cache.key_row(i), cache.value_row(i));
                }
                state
            })
            .collect();
        self.branch = Branch::Recurrent(states);
        self.promoted_at = Some(self.len);
        self.bytes = self.state_bytes();
        true
    }

    /// Append one token's (k, v) and attend with `q`. Inputs are
    /// `[heads, d]` tensors; output concatenates head outputs
    /// feature-wise (same layout as `attention::mhsa` rows). When
    /// `crossover` is given and the new length reaches it, the session
    /// is promoted first so the step itself runs recurrent.
    pub fn step(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        crossover: Option<f64>,
    ) -> StepResult {
        for (name, t) in [("q", q), ("k", k), ("v", v)] {
            assert_eq!(
                t.shape(),
                &[self.heads, self.d],
                "{name} must be [heads={}, d={}]",
                self.heads,
                self.d
            );
        }
        let new_len = self.len + 1;
        let promoted = match crossover {
            Some(n0) if matches!(self.branch, Branch::Kv(_)) && new_len as f64 >= n0 => {
                self.promote()
            }
            _ => false,
        };
        let mut output = Vec::with_capacity(self.heads * self.d);
        match &mut self.branch {
            Branch::Kv(caches) => {
                for (h, cache) in caches.iter_mut().enumerate() {
                    output.extend(cache.decode_step(q.row(h), k.row(h), v.row(h)));
                }
            }
            Branch::Recurrent(states) => {
                for (h, state) in states.iter_mut().enumerate() {
                    output.extend(state.decode_step(q.row(h), k.row(h), v.row(h)));
                }
            }
        }
        self.len = new_len;
        self.bytes = self.state_bytes();
        StepResult {
            output,
            branch: self.branch(),
            promoted,
            len: new_len,
        }
    }
}

/// Closing summary for a finished session.
#[derive(Clone, Debug)]
pub struct SessionSummary {
    pub tokens: usize,
    pub branch: AttentionVariant,
    pub bytes: u64,
    pub promoted_at: Option<usize>,
}

/// Outcome of a store-level decode step.
pub struct StepOutcome {
    pub result: StepResult,
    /// Sessions LRU-evicted to make room during this operation.
    pub evicted: Vec<u64>,
}

/// LRU-evicting, byte-budgeted collection of resident decode sessions.
pub struct SessionStore {
    cfg: DecodeConfig,
    head_dim: usize,
    selector: Selector,
    forced: Option<AttentionVariant>,
    sessions: HashMap<u64, DecodeSession>,
    clock: u64,
    resident_bytes: u64,
}

impl SessionStore {
    /// `forced` mirrors the engine's variant override: `Direct` pins
    /// sessions to the KV path (never promote), `Efficient` starts
    /// them recurrent. `Softmax` has no streaming form and falls back
    /// to the selector policy.
    pub fn new(
        cfg: DecodeConfig,
        head_dim: usize,
        selector: Selector,
        forced: Option<AttentionVariant>,
    ) -> Self {
        Self {
            cfg,
            head_dim,
            selector,
            forced,
            sessions: HashMap::new(),
            clock: 0,
            resident_bytes: 0,
        }
    }

    pub fn config(&self) -> &DecodeConfig {
        &self.cfg
    }

    /// Resident session count.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Total bytes held by resident session state.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Crossover threshold governing KV→recurrent promotion, if any.
    fn promotion_threshold(&self) -> Option<f64> {
        match self.forced {
            Some(AttentionVariant::Direct) | Some(AttentionVariant::Efficient) => None,
            _ => Some(self.selector.crossover(self.head_dim)),
        }
    }

    /// Open (or reset) a session. Returns ids evicted to fit it.
    pub fn open(&mut self, id: u64) -> Vec<u64> {
        let start_recurrent = match self.forced {
            Some(AttentionVariant::Efficient) => true,
            Some(AttentionVariant::Direct) => false,
            // Selector policy: the branch a length-1 prefix would get.
            _ => self.selector.select(1, self.head_dim) == AttentionVariant::Efficient,
        };
        if let Some(old) = self.sessions.remove(&id) {
            self.resident_bytes -= old.bytes;
        }
        let mut session =
            DecodeSession::new(self.cfg.heads, self.head_dim, self.cfg.tau, start_recurrent);
        self.clock += 1;
        session.last_used = self.clock;
        self.resident_bytes += session.bytes;
        self.sessions.insert(id, session);
        self.enforce_budget(Some(id))
    }

    /// One decode step for session `id`. `None` if the session is not
    /// resident (never opened, closed, or evicted).
    pub fn step(&mut self, id: u64, q: &Tensor, k: &Tensor, v: &Tensor) -> Option<StepOutcome> {
        let threshold = self.promotion_threshold();
        self.clock += 1;
        let clock = self.clock;
        let session = self.sessions.get_mut(&id)?;
        let before = session.bytes;
        let result = session.step(q, k, v, threshold);
        let after = session.bytes;
        session.last_used = clock;
        // `before` is included in the resident total, so this never underflows.
        self.resident_bytes = self.resident_bytes - before + after;
        let evicted = self.enforce_budget(Some(id));
        Some(StepOutcome { result, evicted })
    }

    /// Drop a session, returning its closing summary.
    pub fn close(&mut self, id: u64) -> Option<SessionSummary> {
        let session = self.sessions.remove(&id)?;
        self.resident_bytes -= session.bytes;
        Some(SessionSummary {
            tokens: session.len,
            branch: session.branch(),
            bytes: session.bytes,
            promoted_at: session.promoted_at,
        })
    }

    /// Evict LRU sessions until both the byte budget and the session
    /// cap hold. The session named by `protect` (the one being
    /// operated on) is never evicted.
    fn enforce_budget(&mut self, protect: Option<u64>) -> Vec<u64> {
        let mut evicted = Vec::new();
        loop {
            let over_bytes = self.resident_bytes > self.cfg.max_session_bytes;
            let over_count = self.sessions.len() > self.cfg.max_sessions;
            if !over_bytes && !over_count {
                break;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(id, _)| Some(**id) != protect)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(id, _)| *id);
            let Some(victim) = victim else {
                break; // only the protected session remains
            };
            let gone = self.sessions.remove(&victim).expect("victim resident");
            self.resident_bytes -= gone.bytes;
            evicted.push(victim);
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{self, AttentionVariant};

    fn qkv(heads: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::randn(&[heads, d], seed),
            Tensor::randn(&[heads, d], seed + 1),
            Tensor::randn(&[heads, d], seed + 2),
        )
    }

    #[test]
    fn session_promotes_at_crossover_and_stays_continuous() {
        let (heads, d, tau) = (2usize, 4usize, 1.0f32);
        let mut session = DecodeSession::new(heads, d, tau, false);
        let n = 24usize;
        let crossover = 10.0f64;
        // Full per-head history for the reference recompute.
        let mut hist: Vec<(Tensor, Tensor, Tensor)> = Vec::new();
        for t in 0..n {
            let (q, k, v) = qkv(heads, d, 1000 + t as u64 * 3);
            hist.push((q.clone(), k.clone(), v.clone()));
            let r = session.step(&q, &k, &v, Some(crossover));
            assert_eq!(r.promoted, t + 1 == 10);
            let want_variant = if (t + 1) as f64 >= crossover {
                AttentionVariant::Efficient
            } else {
                AttentionVariant::Direct
            };
            assert_eq!(r.branch, want_variant);
            // Reference: full recompute per head with the same variant.
            for h in 0..heads {
                let prefix = t + 1;
                let mut qs = Vec::new();
                let mut ks = Vec::new();
                let mut vs = Vec::new();
                for (qq, kk, vv) in &hist {
                    qs.extend_from_slice(qq.row(h));
                    ks.extend_from_slice(kk.row(h));
                    vs.extend_from_slice(vv.row(h));
                }
                let qp = Tensor::new(&[prefix, d], qs);
                let kp = Tensor::new(&[prefix, d], ks);
                let vp = Tensor::new(&[prefix, d], vs);
                let want = attention::run_variant(want_variant, &qp, &kp, &vp, tau);
                let got = &r.output[h * d..(h + 1) * d];
                let diff: f32 = got
                    .iter()
                    .zip(want.row(t))
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f32::max);
                assert!(diff < 1e-4, "t={t} h={h} diff={diff}");
            }
        }
        assert_eq!(session.promoted_at(), Some(10));
    }

    #[test]
    fn store_evicts_lru_under_byte_budget() {
        let d = 8usize;
        let cfg = DecodeConfig {
            heads: 1,
            // Room for roughly two KV sessions of ~12 tokens each.
            max_session_bytes: 2 * 12 * 2 * d as u64 * 4,
            max_sessions: 16,
            ..DecodeConfig::default()
        };
        let mut store = SessionStore::new(cfg, d, Selector::analytical(), Some(AttentionVariant::Direct));
        let (q, k, v) = qkv(1, d, 7);
        store.open(1);
        store.open(2);
        store.open(3);
        let mut all_evicted = Vec::new();
        for _ in 0..12 {
            for id in [1u64, 2, 3] {
                if store.contains(id) {
                    let out = store.step(id, &q, &k, &v).unwrap();
                    all_evicted.extend(out.evicted);
                }
            }
        }
        assert!(!all_evicted.is_empty(), "budget never triggered eviction");
        assert!(store.resident_bytes() <= store.config().max_session_bytes);
        // Evicted sessions are gone: step returns None.
        let gone = all_evicted[0];
        assert!(store.step(gone, &q, &k, &v).is_none());
    }

    #[test]
    fn store_caps_session_count() {
        let cfg = DecodeConfig {
            heads: 1,
            max_sessions: 2,
            ..DecodeConfig::default()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        assert!(store.open(1).is_empty());
        assert!(store.open(2).is_empty());
        let evicted = store.open(3);
        assert_eq!(evicted, vec![1], "oldest session evicted");
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn lru_order_follows_use_not_creation() {
        let cfg = DecodeConfig {
            heads: 1,
            max_sessions: 2,
            ..DecodeConfig::default()
        };
        let mut store = SessionStore::new(cfg, 4, Selector::analytical(), None);
        let (q, k, v) = qkv(1, 4, 9);
        store.open(1);
        store.open(2);
        store.step(1, &q, &k, &v).unwrap(); // 1 is now most recent
        let evicted = store.open(3);
        assert_eq!(evicted, vec![2]);
        assert!(store.contains(1) && store.contains(3));
    }

    #[test]
    fn forced_direct_never_promotes() {
        let mut store = SessionStore::new(
            DecodeConfig { heads: 1, ..DecodeConfig::default() },
            2, // crossover N0(2) is tiny — would promote immediately
            Selector::analytical(),
            Some(AttentionVariant::Direct),
        );
        let (q, k, v) = qkv(1, 2, 3);
        store.open(5);
        for _ in 0..32 {
            let out = store.step(5, &q, &k, &v).unwrap();
            assert_eq!(out.result.branch, AttentionVariant::Direct);
            assert!(!out.result.promoted);
        }
    }

    #[test]
    fn forced_efficient_starts_recurrent() {
        let mut store = SessionStore::new(
            DecodeConfig { heads: 1, ..DecodeConfig::default() },
            16,
            Selector::analytical(),
            Some(AttentionVariant::Efficient),
        );
        let (q, k, v) = qkv(1, 16, 4);
        store.open(5);
        let out = store.step(5, &q, &k, &v).unwrap();
        assert_eq!(out.result.branch, AttentionVariant::Efficient);
        assert!(!out.result.promoted, "no promotion event when born recurrent");
    }

    #[test]
    fn close_reports_summary_and_frees_bytes() {
        let mut store = SessionStore::new(
            DecodeConfig { heads: 2, ..DecodeConfig::default() },
            4,
            Selector::analytical(),
            None,
        );
        let (q, k, v) = qkv(2, 4, 11);
        store.open(9);
        for _ in 0..3 {
            store.step(9, &q, &k, &v).unwrap();
        }
        let summary = store.close(9).unwrap();
        assert_eq!(summary.tokens, 3);
        assert_eq!(store.resident_bytes(), 0);
        assert!(store.close(9).is_none());
    }
}

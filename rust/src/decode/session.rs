//! Per-layer decode state: one streaming attention context.
//!
//! A [`DecodeSession`] holds one multi-head streaming context — in the
//! whole-model path, *one transformer layer's* attention state. It
//! starts on the branch the selector picks for a length-1 prefix
//! (direct/KV below the crossover) and is *promoted* to the recurrent
//! moment state when its length crosses N₀(d) — the paper's "(and
//! Back)" switch applied at decode time. Promotion replays the cached
//! (k, v) pairs into [`RecurrentState`] once (O(N·d³)); because the
//! two branches compute the same function, the output stream is
//! continuous across the switch.
//!
//! Residency (LRU eviction under a byte budget) lives one level up:
//! [`crate::model::SessionStore`] keeps whole-model
//! [`crate::model::ModelSession`]s — stacks of these per-layer
//! sessions — resident, with byte accounting summed across layers.

use super::kv::KvCache;
use super::recurrent::RecurrentState;
use crate::attention::AttentionVariant;
use crate::tensor::Tensor;
use crate::util::bytes::{ByteReader, ByteWriter, CodecError};
use std::path::PathBuf;

/// Spill-tier configuration: where evicted session state goes and how
/// much disk it may occupy. Disabled by default — eviction then
/// destroys state and the next step answers `NeedsReprefill`.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SpillConfig {
    /// Master switch for the disk tier.
    pub enabled: bool,
    /// Spill directory; `None` picks a per-process temp dir. Setting a
    /// dir without `enabled` is a config error (builder-validated).
    pub dir: Option<PathBuf>,
    /// Byte budget for on-disk spill files. Oldest spilled sessions
    /// are dropped (second-level eviction) to make room. Zero means
    /// "use the default" when built through `EngineConfig::builder()`.
    pub max_bytes: u64,
}

impl SpillConfig {
    /// Default on-disk budget when `max_bytes` is left at 0.
    pub const DEFAULT_MAX_BYTES: u64 = 256 << 20;

    /// An enabled tier with the default budget, spilling to `dir`.
    pub fn enabled_in(dir: PathBuf) -> Self {
        Self {
            enabled: true,
            dir: Some(dir),
            max_bytes: Self::DEFAULT_MAX_BYTES,
        }
    }
}

/// Decode-subsystem configuration (engine-level).
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeConfig {
    /// Attention heads per streaming session.
    pub heads: usize,
    /// Temperature shared by both branches (broadcast to every layer
    /// unless `layer_taus` is set).
    pub tau: f32,
    /// Transformer blocks in the streaming model.
    pub n_layers: usize,
    /// Hidden width of each block's MLP.
    pub d_ff: usize,
    /// Optional per-layer temperatures; empty broadcasts `tau`. When
    /// non-empty its length must equal `n_layers`.
    pub layer_taus: Vec<f32>,
    /// Weight-init seed for the deterministic streaming model.
    pub model_seed: u64,
    /// Total resident-state budget across sessions, in bytes.
    pub max_session_bytes: u64,
    /// Hard cap on resident sessions regardless of bytes.
    pub max_sessions: usize,
    /// Max decode steps the engine serves ahead of due prefill batches
    /// in one drive cycle (the decode/prefill mixing knob).
    pub max_steps_per_cycle: usize,
    /// Disk spill tier for evicted sessions.
    pub spill: SpillConfig,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        Self {
            heads: 4,
            tau: 1.0,
            n_layers: 2,
            d_ff: 128,
            layer_taus: Vec::new(),
            model_seed: 42,
            max_session_bytes: 64 << 20,
            max_sessions: 256,
            max_steps_per_cycle: 64,
            spill: SpillConfig::default(),
        }
    }
}

enum Branch {
    Kv(Vec<KvCache>),
    Recurrent(Vec<RecurrentState>),
}

/// Result of one decode step.
#[derive(Clone, Debug)]
pub struct StepResult {
    /// Concatenated per-head outputs, length `heads · d`.
    pub output: Vec<f32>,
    /// Branch that produced this step.
    pub branch: AttentionVariant,
    /// True iff this step triggered the KV→recurrent promotion.
    pub promoted: bool,
    /// Prefix length after this step.
    pub len: usize,
}

/// One multi-head streaming decode context (one layer's state).
pub struct DecodeSession {
    heads: usize,
    d: usize,
    len: usize,
    branch: Branch,
    promoted_at: Option<usize>,
}

impl DecodeSession {
    /// A fresh session. `start_recurrent` skips the KV phase entirely
    /// (used when the variant is forced to Efficient).
    pub fn new(heads: usize, d: usize, tau: f32, start_recurrent: bool) -> Self {
        assert!(heads > 0 && d > 0, "heads and head dim must be positive");
        let branch = if start_recurrent {
            Branch::Recurrent((0..heads).map(|_| RecurrentState::new(d, tau)).collect())
        } else {
            Branch::Kv((0..heads).map(|_| KvCache::new(d, tau)).collect())
        };
        Self {
            heads,
            d,
            len: 0,
            branch,
            promoted_at: None,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.d
    }

    /// Variant currently serving this session.
    pub fn branch(&self) -> AttentionVariant {
        match self.branch {
            Branch::Kv(_) => AttentionVariant::Direct,
            Branch::Recurrent(_) => AttentionVariant::Efficient,
        }
    }

    /// Prefix length at which the session switched to recurrent state
    /// (the length *including* the promoting token).
    pub fn promoted_at(&self) -> Option<usize> {
        self.promoted_at
    }

    /// Resident bytes of this session's state.
    pub fn state_bytes(&self) -> u64 {
        match &self.branch {
            Branch::Kv(caches) => caches.iter().map(KvCache::state_bytes).sum(),
            Branch::Recurrent(states) => states.iter().map(RecurrentState::state_bytes).sum(),
        }
    }

    /// Switch KV → recurrent by replaying the cached prefix into the
    /// moment accumulators (one-time O(N·d³)). No-op if already
    /// recurrent. Exact: the cached keys are already normalized and
    /// both branches compute the same attention function.
    pub fn promote(&mut self) -> bool {
        let Branch::Kv(caches) = &self.branch else {
            return false;
        };
        let states: Vec<RecurrentState> = caches
            .iter()
            .map(|cache| {
                let mut state = RecurrentState::new(self.d, cache.tau());
                for i in 0..cache.len() {
                    state.append(cache.key_row(i), cache.value_row(i));
                }
                state
            })
            .collect();
        self.branch = Branch::Recurrent(states);
        self.promoted_at = Some(self.len);
        true
    }

    /// Append one token's (k, v) and attend with `q`. Inputs are
    /// `[heads, d]` tensors; output concatenates head outputs
    /// feature-wise (same layout as `attention::mhsa` rows). When
    /// `crossover` is given and the new length reaches it, the session
    /// is promoted first so the step itself runs recurrent.
    pub fn step(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        crossover: Option<f64>,
    ) -> StepResult {
        for (name, t) in [("q", q), ("k", k), ("v", v)] {
            assert_eq!(
                t.shape(),
                &[self.heads, self.d],
                "{name} must be [heads={}, d={}]",
                self.heads,
                self.d
            );
        }
        let new_len = self.len + 1;
        let promoted = match crossover {
            Some(n0) if matches!(self.branch, Branch::Kv(_)) && new_len as f64 >= n0 => {
                let _promote_span = crate::obs::span("decode.promote");
                self.promote()
            }
            _ => false,
        };
        if promoted {
            // `promote()` ran before the length bump; the recorded
            // prefix must include the promoting token.
            self.promoted_at = Some(new_len);
        }
        let step_span_name = match &self.branch {
            Branch::Kv(_) => "decode.kv_step",
            Branch::Recurrent(_) => "decode.recurrent_step",
        };
        let step_span = crate::obs::span(step_span_name);
        let mut output = Vec::with_capacity(self.heads * self.d);
        match &mut self.branch {
            Branch::Kv(caches) => {
                for (h, cache) in caches.iter_mut().enumerate() {
                    output.extend(cache.decode_step(q.row(h), k.row(h), v.row(h)));
                }
            }
            Branch::Recurrent(states) => {
                for (h, state) in states.iter_mut().enumerate() {
                    output.extend(state.decode_step(q.row(h), k.row(h), v.row(h)));
                }
            }
        }
        drop(step_span);
        self.len = new_len;
        StepResult {
            output,
            branch: self.branch(),
            promoted,
            len: new_len,
        }
    }

    /// Serialize this layer's state bit-exactly (spill path): header,
    /// branch tag, then each head's KV cache or moment accumulators.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.put_u32(self.heads as u32);
        w.put_u32(self.d as u32);
        w.put_u64(self.len as u64);
        match self.promoted_at {
            Some(at) => {
                w.put_u8(1);
                w.put_u64(at as u64);
            }
            None => w.put_u8(0),
        }
        match &self.branch {
            Branch::Kv(caches) => {
                w.put_u8(0);
                for cache in caches {
                    cache.encode(w);
                }
            }
            Branch::Recurrent(states) => {
                w.put_u8(1);
                for state in states {
                    state.encode(w);
                }
            }
        }
    }

    /// Inverse of [`DecodeSession::encode`]. Structural validation
    /// only; payload integrity is the spill layer's checksum.
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self, CodecError> {
        let heads = r.get_u32()? as usize;
        let d = r.get_u32()? as usize;
        if heads == 0 || d == 0 || heads > 1 << 12 {
            return Err(CodecError::Invalid { what: "session shape" });
        }
        let len = r.get_u64()? as usize;
        let promoted_at = match r.get_u8()? {
            0 => None,
            1 => Some(r.get_u64()? as usize),
            tag => return Err(CodecError::BadTag { what: "promoted_at", tag }),
        };
        let branch = match r.get_u8()? {
            0 => {
                let mut caches = Vec::with_capacity(heads);
                for _ in 0..heads {
                    let cache = KvCache::decode(r)?;
                    if cache.head_dim() != d || cache.len() != len {
                        return Err(CodecError::Invalid { what: "kv head state" });
                    }
                    caches.push(cache);
                }
                Branch::Kv(caches)
            }
            1 => {
                let mut states = Vec::with_capacity(heads);
                for _ in 0..heads {
                    let state = RecurrentState::decode(r)?;
                    if state.head_dim() != d || state.len() != len {
                        return Err(CodecError::Invalid { what: "recurrent head state" });
                    }
                    states.push(state);
                }
                Branch::Recurrent(states)
            }
            tag => return Err(CodecError::BadTag { what: "branch", tag }),
        };
        Ok(Self {
            heads,
            d,
            len,
            branch,
            promoted_at,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{self, AttentionVariant};

    fn qkv(heads: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::randn(&[heads, d], seed),
            Tensor::randn(&[heads, d], seed + 1),
            Tensor::randn(&[heads, d], seed + 2),
        )
    }

    #[test]
    fn session_promotes_at_crossover_and_stays_continuous() {
        let (heads, d, tau) = (2usize, 4usize, 1.0f32);
        let mut session = DecodeSession::new(heads, d, tau, false);
        let n = 24usize;
        let crossover = 10.0f64;
        // Full per-head history for the reference recompute.
        let mut hist: Vec<(Tensor, Tensor, Tensor)> = Vec::new();
        for t in 0..n {
            let (q, k, v) = qkv(heads, d, 1000 + t as u64 * 3);
            hist.push((q.clone(), k.clone(), v.clone()));
            let r = session.step(&q, &k, &v, Some(crossover));
            assert_eq!(r.promoted, t + 1 == 10);
            let want_variant = if (t + 1) as f64 >= crossover {
                AttentionVariant::Efficient
            } else {
                AttentionVariant::Direct
            };
            assert_eq!(r.branch, want_variant);
            // Reference: full recompute per head with the same variant.
            for h in 0..heads {
                let prefix = t + 1;
                let mut qs = Vec::new();
                let mut ks = Vec::new();
                let mut vs = Vec::new();
                for (qq, kk, vv) in &hist {
                    qs.extend_from_slice(qq.row(h));
                    ks.extend_from_slice(kk.row(h));
                    vs.extend_from_slice(vv.row(h));
                }
                let qp = Tensor::new(&[prefix, d], qs);
                let kp = Tensor::new(&[prefix, d], ks);
                let vp = Tensor::new(&[prefix, d], vs);
                let want = attention::run_variant(want_variant, &qp, &kp, &vp, tau);
                let got = &r.output[h * d..(h + 1) * d];
                let diff: f32 = got
                    .iter()
                    .zip(want.row(t))
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f32::max);
                assert!(diff < 1e-4, "t={t} h={h} diff={diff}");
            }
        }
        assert_eq!(session.promoted_at(), Some(10));
    }

    #[test]
    fn encode_decode_roundtrip_across_promotion() {
        let (heads, d, tau) = (2usize, 4usize, 1.0f32);
        for promote_first in [false, true] {
            let mut session = DecodeSession::new(heads, d, tau, false);
            for t in 0..8 {
                let (q, k, v) = qkv(heads, d, 500 + t * 7);
                session.step(&q, &k, &v, None);
            }
            if promote_first {
                session.promote();
            }
            let mut w = crate::util::bytes::ByteWriter::new();
            session.encode(&mut w);
            let bytes = w.into_bytes();
            let mut r = crate::util::bytes::ByteReader::new(&bytes);
            let mut back = DecodeSession::decode(&mut r).unwrap();
            assert_eq!(r.remaining(), 0);
            assert_eq!(back.len(), session.len());
            assert_eq!(back.branch(), session.branch());
            assert_eq!(back.promoted_at(), session.promoted_at());
            assert_eq!(back.state_bytes(), session.state_bytes());
            // Future steps must be bit-exact against the original.
            let (q, k, v) = qkv(heads, d, 900);
            let a = session.step(&q, &k, &v, None);
            let b = back.step(&q, &k, &v, None);
            let eq = a
                .output
                .iter()
                .zip(&b.output)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "promote_first={promote_first}: restored step must be bit-exact");
        }
    }

    #[test]
    fn state_bytes_track_branch() {
        let mut session = DecodeSession::new(1, 4, 1.0, false);
        let empty_kv = session.state_bytes();
        let (q, k, v) = qkv(1, 4, 21);
        session.step(&q, &k, &v, None);
        assert!(session.state_bytes() > empty_kv, "KV bytes grow with tokens");
        session.promote();
        let recurrent = session.state_bytes();
        session.step(&q, &k, &v, None);
        assert_eq!(session.state_bytes(), recurrent, "recurrent bytes are flat");
    }
}

//! Host tensors ⇄ `xla::Literal` conversions.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// f32 `Tensor` → literal with the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .context("reshaping tensor literal")
}

/// Literal → f32 `Tensor` (must be an f32 array literal).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&x| x as usize).collect();
    let data = lit.to_vec::<f32>().context("literal is not f32")?;
    Ok(Tensor::new(&dims, data))
}

/// i32 token matrix → literal (B, N).
pub fn tokens_to_literal(tokens: &[Vec<i32>]) -> Result<xla::Literal> {
    if tokens.is_empty() {
        bail!("empty token batch");
    }
    let n = tokens[0].len();
    if tokens.iter().any(|row| row.len() != n) {
        bail!("ragged token batch");
    }
    let flat: Vec<i32> = tokens.iter().flatten().copied().collect();
    xla::Literal::vec1(&flat)
        .reshape(&[tokens.len() as i64, n as i64])
        .context("reshaping token literal")
}

/// i32 vector literal (labels).
pub fn labels_to_literal(labels: &[i32]) -> xla::Literal {
    xla::Literal::vec1(labels)
}

/// i32 scalar literal (step counter).
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// f32 scalar readback.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("reading f32 scalar")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::randn(&[3, 5], 1);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn tokens_shape() {
        let lit = tokens_to_literal(&[vec![1, 2, 3], vec![4, 5, 6]]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn ragged_tokens_rejected() {
        assert!(tokens_to_literal(&[vec![1], vec![2, 3]]).is_err());
    }

    #[test]
    fn scalar_readback() {
        let lit = xla::Literal::scalar(2.5f32);
        assert_eq!(literal_to_f32(&lit).unwrap(), 2.5);
    }
}

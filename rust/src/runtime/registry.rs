//! Artifact registry: reads `artifacts/manifest.json`, lazily compiles
//! HLO modules on first use, and caches executables by name.
//!
//! Also loads the `.params.bin` initial-parameter blobs the AOT
//! pipeline writes next to train/infer artifacts (flat little-endian
//! f32 in manifest order).

use super::client::Runtime;
use super::executable::{ArtifactKind, Executable, IoSpec, TensorSpec};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Manifest-driven artifact store with an executable cache.
pub struct Registry {
    runtime: Runtime,
    dir: PathBuf,
    manifest: Json,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Registry {
    /// Open `dir` (usually `artifacts/`), reading `manifest.json`.
    pub fn open(runtime: Runtime, dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let version = manifest.get("version").and_then(|v| v.as_usize());
        if version != Some(1) {
            bail!("unsupported manifest version {version:?}");
        }
        Ok(Self {
            runtime,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// All artifact names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.manifest
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Artifact names filtered by kind.
    pub fn names_of_kind(&self, kind: ArtifactKind) -> Vec<String> {
        self.names()
            .into_iter()
            .filter(|n| {
                self.entry(n)
                    .ok()
                    .and_then(|e| e.get("kind").and_then(|k| k.as_str()).map(String::from))
                    .and_then(|k| ArtifactKind::parse(&k).ok())
                    == Some(kind)
            })
            .collect()
    }

    /// Raw manifest entry.
    pub fn entry(&self, name: &str) -> Result<&Json> {
        self.manifest
            .get("artifacts")
            .and_then(|a| a.get(name))
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Whether an artifact exists.
    pub fn contains(&self, name: &str) -> bool {
        self.entry(name).is_ok()
    }

    /// Compile (or fetch cached) an artifact.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let entry = self.entry(name)?.clone();
        let kind = ArtifactKind::parse(
            entry
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| anyhow!("artifact '{name}' missing kind"))?,
        )?;
        let path = self.dir.join(
            entry
                .get("path")
                .and_then(|p| p.as_str())
                .ok_or_else(|| anyhow!("artifact '{name}' missing path"))?,
        );
        let io = Self::io_spec(&entry)?;
        let exe = self.runtime.compile_hlo_file(&path)?;
        let executable = Arc::new(Executable::new(name.to_string(), kind, io, entry, exe));
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&executable));
        Ok(executable)
    }

    fn io_spec(entry: &Json) -> Result<IoSpec> {
        let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
            entry
                .get(key)
                .and_then(|x| x.as_arr())
                .map(|items| items.iter().map(TensorSpec::from_json).collect())
                .unwrap_or_else(|| Ok(Vec::new()))
        };
        Ok(IoSpec {
            inputs: parse_list("inputs")?,
            outputs: parse_list("outputs")?,
            params: parse_list("params")?,
        })
    }

    /// Load the initial parameters for a train/infer artifact: the flat
    /// f32 blob is split per the manifest's param shapes.
    pub fn load_params(&self, name: &str) -> Result<Vec<crate::tensor::Tensor>> {
        let entry = self.entry(name)?;
        let bin = entry
            .get("params_bin")
            .and_then(|p| p.as_str())
            .ok_or_else(|| anyhow!("artifact '{name}' has no params_bin"))?;
        let bytes = std::fs::read(self.dir.join(bin))
            .with_context(|| format!("reading params blob {bin}"))?;
        let specs = Self::io_spec(entry)?.params;
        let total: usize = specs.iter().map(|s| s.elements()).sum();
        if bytes.len() != total * 4 {
            bail!(
                "params blob {bin}: {} bytes but manifest wants {} f32s",
                bytes.len(),
                total
            );
        }
        let mut tensors = Vec::with_capacity(specs.len());
        let mut offset = 0usize;
        for spec in &specs {
            let count = spec.elements();
            let data: Vec<f32> = bytes[offset * 4..(offset + count) * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.push(crate::tensor::Tensor::new(&spec.shape, data));
            offset += count;
        }
        Ok(tensors)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

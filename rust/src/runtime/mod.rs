//! PJRT runtime: loads AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`), compiles them on the CPU PJRT client, and
//! executes them from the L3 hot path — plus an `XlaBuilder`-based
//! attention **emitter** that constructs the same attention
//! computations natively in rust for arbitrary `(N, d)`, giving the
//! coordinator runtime shape specialization with python nowhere in
//! sight.

pub mod client;
pub mod emitter;
pub mod executable;
pub mod literal;
pub mod registry;

pub use client::Runtime;
pub use executable::{ArtifactKind, Executable, IoSpec, TensorSpec};
pub use registry::Registry;

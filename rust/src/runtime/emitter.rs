//! Rust-native attention emitter: builds the three attention variants
//! directly with `XlaBuilder` for ANY `(N, d)` at runtime.
//!
//! This is what lets the coordinator specialize executables to new
//! sequence lengths without touching python — the AOT grid covers the
//! common buckets; the emitter covers the tail (and powers the Fig. 2
//! benchmark sweep, which needs dozens of N values per d). Parity with
//! the jax-lowered artifacts and the pure-rust reference is enforced by
//! integration tests (`rust/tests/runtime_parity.rs`).

use super::client::Runtime;
use anyhow::{Context, Result};
use xla::{ElementType, XlaBuilder, XlaComputation, XlaOp};

/// Which computation to emit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EmitVariant {
    TaylorDirect,
    TaylorEfficient,
    Softmax,
}

impl From<crate::attention::AttentionVariant> for EmitVariant {
    fn from(v: crate::attention::AttentionVariant) -> Self {
        match v {
            crate::attention::AttentionVariant::Direct => Self::TaylorDirect,
            crate::attention::AttentionVariant::Efficient => Self::TaylorEfficient,
            crate::attention::AttentionVariant::Softmax => Self::Softmax,
        }
    }
}

const F32: ElementType = ElementType::F32;

/// Row-wise l2 normalization scaled by `scale`.
/// (XLA binary ops broadcast degenerate dims for same-rank operands, so
/// (N,d) ∘ (N,1) works without explicit BroadcastInDim.)
fn normalize_rows(b: &XlaBuilder, x: &XlaOp, scale: f32) -> Result<XlaOp> {
    let sumsq = x.mul_(x)?.reduce_sum(&[1], true)?; // (N, 1)
    let norm = sumsq.sqrt()?.max(&b.c0(1e-12f32)?)?;
    x.div_(&norm)?.mul_(&b.c0(scale)?).context("scaling rows")
}

/// Row-wise tensor product A ⊠ A → (N, d²): reshape + degenerate
/// broadcast multiply, exactly the Algorithm 1 Line 1-3 definition.
fn boxtimes_self(x: &XlaOp, n: i64, d: i64) -> Result<XlaOp> {
    let left = x.reshape(&[n, d, 1])?;
    let right = x.reshape(&[n, 1, d])?;
    left.mul_(&right)?
        .reshape(&[n, d * d])
        .context("boxtimes reshape")
}

/// Build `f(q, k, v) -> (y,)` for one head at shape `(n, d)`, with the
/// paper's normalization and temperature `tau` baked in as constants.
pub fn build_attention(
    variant: EmitVariant,
    n: usize,
    d: usize,
    tau: f32,
) -> Result<XlaComputation> {
    let b = XlaBuilder::new(&format!("attn_{variant:?}_n{n}_d{d}"));
    let (ni, di) = (n as i64, d as i64);
    let q = b.parameter(0, F32, &[ni, di], "q")?;
    let k = b.parameter(1, F32, &[ni, di], "k")?;
    let v = b.parameter(2, F32, &[ni, di], "v")?;
    let y = match variant {
        EmitVariant::Softmax => emit_softmax(&b, &q, &k, &v, d)?,
        EmitVariant::TaylorDirect => emit_direct(&b, &q, &k, &v, n, d, tau)?,
        EmitVariant::TaylorEfficient => emit_efficient(&b, &q, &k, &v, n, d, tau)?,
    };
    // Match the AOT artifacts' return_tuple=True convention.
    let root = b.tuple(&[y])?;
    b.build(&root).context("building attention computation")
}

fn emit_softmax(b: &XlaBuilder, q: &XlaOp, k: &XlaOp, v: &XlaOp, d: usize) -> Result<XlaOp> {
    let scores = q
        .matmul(&k.transpose(&[1, 0])?)?
        .mul_(&b.c0(1.0 / (d as f32).sqrt())?)?;
    let weights = scores.softmax(1)?;
    weights.matmul(v).context("softmax @ V")
}

fn emit_direct(
    b: &XlaBuilder,
    q: &XlaOp,
    k: &XlaOp,
    v: &XlaOp,
    n: usize,
    d: usize,
    tau: f32,
) -> Result<XlaOp> {
    let qn = normalize_rows(b, q, tau)?;
    let kn = normalize_rows(b, k, 1.0)?;
    let s = qn.matmul(&kn.transpose(&[1, 0])?)?;
    // a = 1 + s + s²/2
    let a = b
        .c0(1.0f32)?
        .add_(&s)?
        .add_(&s.mul_(&s)?.mul_(&b.c0(0.5f32)?)?)?;
    let denom = a.reduce_sum(&[1], true)?;
    let y = a.matmul(v)?.div_(&denom)?;
    y.mul_(&b.c0((n as f32 / d as f32).sqrt())?)
        .context("output scale")
}

fn emit_efficient(
    b: &XlaBuilder,
    q: &XlaOp,
    k: &XlaOp,
    v: &XlaOp,
    n: usize,
    d: usize,
    tau: f32,
) -> Result<XlaOp> {
    let (ni, di) = (n as i64, d as i64);
    let alpha = (d as f32).powf(0.25);

    // V_aug = (1/N) [sqrt(d/N)·1 | V]  — (N, d+1)
    let denom_col = b
        .c0((d as f32 / n as f32).sqrt() / n as f32)?
        .broadcast(&[ni, 1])?;
    let v_scaled = v.mul_(&b.c0(1.0 / n as f32)?)?;
    let v_aug = denom_col.concat_in_dim(&[&v_scaled], 1)?;

    let qn = normalize_rows(b, q, alpha * tau)?;
    let kn = normalize_rows(b, k, alpha)?;

    // A_mod = (K⊠K)ᵀ V_aug — (d², d+1)
    let kbox = boxtimes_self(&kn, ni, di)?;
    let a_mod = kbox.transpose(&[1, 0])?.matmul(&v_aug)?;

    // Ŷ = ½ (Q⊠Q) A_mod + α² Q (Kᵀ V_aug) + α⁴ Σ_col V_aug
    let qbox = boxtimes_self(&qn, ni, di)?;
    let y_sq = qbox.matmul(&a_mod)?;
    let ktv = kn.transpose(&[1, 0])?.matmul(&v_aug)?;
    let y_lin = qn.matmul(&ktv)?;
    let col_sums = v_aug.reduce_sum(&[0], true)?; // (1, d+1)
    let y_hat = y_sq
        .mul_(&b.c0(0.5f32)?)?
        .add_(&y_lin.mul_(&b.c0(alpha * alpha)?)?)?
        .add_(&col_sums.mul_(&b.c0(alpha.powi(4))?)?)?;

    // Split off the denominator column, divide.
    let y_denom = y_hat.slice_in_dim1(0, 1, 1)?; // (N, 1)
    let y_nom = y_hat.slice_in_dim1(1, di + 1, 1)?; // (N, d)
    y_nom.div_(&y_denom).context("final division")
}

/// Build multi-head self-attention `f(q, k, v) -> (y,)` where
/// `q/k/v: (h, n, d)` are the already-projected per-head tensors and
/// `y: (n, h·d)` concatenates head outputs feature-wise. Heads unroll
/// into one fused XLA graph — this is what the Table 5 / Fig. 9 head-
/// scaling benches execute.
pub fn build_mhsa(
    variant: EmitVariant,
    n: usize,
    d: usize,
    h: usize,
    tau: f32,
) -> Result<XlaComputation> {
    let b = XlaBuilder::new(&format!("mhsa_{variant:?}_n{n}_d{d}_h{h}"));
    let (ni, di, hi) = (n as i64, d as i64, h as i64);
    let q = b.parameter(0, F32, &[hi, ni, di], "q")?;
    let k = b.parameter(1, F32, &[hi, ni, di], "k")?;
    let v = b.parameter(2, F32, &[hi, ni, di], "v")?;
    let mut heads = Vec::with_capacity(h);
    for head in 0..hi {
        let slice = |t: &XlaOp| -> Result<XlaOp> {
            Ok(t.slice_in_dim1(head, head + 1, 0)?.reshape(&[ni, di])?)
        };
        let (qh, kh, vh) = (slice(&q)?, slice(&k)?, slice(&v)?);
        let y = match variant {
            EmitVariant::Softmax => emit_softmax(&b, &qh, &kh, &vh, d)?,
            EmitVariant::TaylorDirect => emit_direct(&b, &qh, &kh, &vh, n, d, tau)?,
            EmitVariant::TaylorEfficient => emit_efficient(&b, &qh, &kh, &vh, n, d, tau)?,
        };
        heads.push(y);
    }
    let first = heads[0].clone();
    let rest: Vec<&XlaOp> = heads[1..].iter().collect();
    let y = if rest.is_empty() {
        first
    } else {
        first.concat_in_dim(&rest, 1)?
    };
    let root = b.tuple(&[y])?;
    b.build(&root).context("building mhsa computation")
}

/// Emit + compile in one step.
pub fn compile_attention(
    runtime: &Runtime,
    variant: EmitVariant,
    n: usize,
    d: usize,
    tau: f32,
) -> Result<xla::PjRtLoadedExecutable> {
    let computation = build_attention(variant, n, d, tau)?;
    runtime.compile(&computation)
}

/// Convenience: run a compiled single-head attention on host tensors.
pub fn run_attention(
    exe: &xla::PjRtLoadedExecutable,
    q: &crate::tensor::Tensor,
    k: &crate::tensor::Tensor,
    v: &crate::tensor::Tensor,
) -> Result<crate::tensor::Tensor> {
    let inputs = [
        super::literal::tensor_to_literal(q)?,
        super::literal::tensor_to_literal(k)?,
        super::literal::tensor_to_literal(v)?,
    ];
    let result = exe.execute::<xla::Literal>(&inputs)?[0][0]
        .to_literal_sync()
        .context("fetching attention output")?;
    let out = result.to_tuple1().context("unwrapping 1-tuple")?;
    super::literal::literal_to_tensor(&out)
}

//! Thin wrapper around the PJRT CPU client.
//!
//! One `Runtime` per process; executables and buffers keep a reference
//! to it. (The `xla` crate's `PjRtClient` is internally refcounted, so
//! clones share the underlying client.)

use anyhow::{Context, Result};

/// Process-wide PJRT client handle.
#[derive(Clone)]
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client. ~100 ms; do it once.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile an HLO-text file into an executable.
    pub fn compile_hlo_file(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&computation)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Compile an in-memory computation (emitter path).
    pub fn compile(&self, computation: &xla::XlaComputation) -> Result<xla::PjRtLoadedExecutable> {
        self.client
            .compile(computation)
            .context("compiling built computation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }
}

//! A compiled artifact plus its manifest metadata.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Tensor IO description from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" | "s32" | "u8"
    pub dtype: String,
}

impl TensorSpec {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j
                .get("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(|x| x.as_usize_vec())
                .ok_or_else(|| anyhow!("spec missing shape"))?,
            dtype: j
                .get("dtype")
                .and_then(|x| x.as_str())
                .unwrap_or("f32")
                .to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// IO signature of an artifact.
#[derive(Clone, Debug, Default)]
pub struct IoSpec {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Parameter leaves in flatten order (train/infer/eval artifacts).
    pub params: Vec<TensorSpec>,
}

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Infer,
    Train,
    Eval,
    Attention,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "infer" => Self::Infer,
            "train" => Self::Train,
            "eval" => Self::Eval,
            "attention" => Self::Attention,
            other => bail!("unknown artifact kind {other}"),
        })
    }
}

/// A compiled, ready-to-run artifact.
pub struct Executable {
    pub name: String,
    pub kind: ArtifactKind,
    pub io: IoSpec,
    pub batch: Option<usize>,
    pub seq_len: Option<usize>,
    pub num_params: usize,
    /// Raw manifest entry for artifact-kind-specific fields.
    pub meta: Json,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub fn new(
        name: String,
        kind: ArtifactKind,
        io: IoSpec,
        meta: Json,
        exe: xla::PjRtLoadedExecutable,
    ) -> Self {
        let batch = meta.get("batch").and_then(|x| x.as_usize());
        let seq_len = meta.get("seq_len").and_then(|x| x.as_usize());
        let num_params = meta.get("num_params").and_then(|x| x.as_usize()).unwrap_or(0);
        Self {
            name,
            kind,
            io,
            batch,
            seq_len,
            num_params,
            meta,
            exe,
        }
    }

    /// Execute with host literals (owned or borrowed — borrowing avoids
    /// copying large parameter sets on the hot path); returns the
    /// decomposed output tuple. (aot.py lowers with `return_tuple=True`.)
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.io.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.io.inputs.len(),
                inputs.len()
            );
        }
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        tuple.to_tuple().context("decomposing result tuple")
    }

    /// Execute keeping results on device (hot loops: train steps feed
    /// outputs back as inputs without host round-trips).
    pub fn run_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing {} (buffers)", self.name))?;
        Ok(result.swap_remove(0))
    }

    pub fn raw(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }
}

//! Benchmark harness (criterion stand-in).
//!
//! Warmup + timed iterations with trimmed statistics, plus a fixed-width
//! table printer so every bench regenerates its paper table/figure as
//! aligned rows on stdout (and optionally as JSON for plotting).

use crate::util::json::Json;
use crate::util::stats;
use std::time::Instant;

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub label: String,
    /// Trimmed mean seconds per iteration.
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl Timing {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("label", Json::Str(self.label.clone())),
            ("mean_s", Json::Num(self.mean_s)),
            ("median_s", Json::Num(self.median_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("min_s", Json::Num(self.min_s)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// Bench configuration: time-budgeted with iteration caps.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop when this much measurement time has accumulated.
    pub target_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 5,
            max_iters: 200,
            target_seconds: 1.0,
        }
    }
}

impl BenchConfig {
    /// Lighter settings for expensive cases (long sequences).
    pub fn heavy() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            target_seconds: 2.0,
        }
    }

    /// Quick mode for CI/smoke (env `TS_BENCH_QUICK=1`).
    pub fn from_env() -> Self {
        if std::env::var("TS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 5,
                target_seconds: 0.2,
            }
        } else {
            Self::default()
        }
    }
}

/// Time `f`, which performs ONE iteration of the workload per call.
pub fn bench(label: impl Into<String>, config: &BenchConfig, mut f: impl FnMut()) -> Timing {
    for _ in 0..config.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(config.max_iters);
    let budget_start = Instant::now();
    while samples.len() < config.min_iters
        || (samples.len() < config.max_iters
            && budget_start.elapsed().as_secs_f64() < config.target_seconds)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        label: label.into(),
        mean_s: stats::trimmed_mean(&samples, 0.1),
        median_s: stats::median(&samples),
        p95_s: stats::percentile(&samples, 0.95),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: samples.len(),
    }
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (cell, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", cell, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Human formatting helpers used across benches.
pub fn fmt_seconds(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

pub fn fmt_mib(bytes: f64) -> String {
    format!("{:.1} MiB", bytes / (1024.0 * 1024.0))
}

/// Write a bench's JSON series next to stdout output (under `bench_out/`).
pub fn write_json(name: &str, value: &Json) {
    let dir = std::path::Path::new("bench_out");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(format!("{name}.json"));
        let _ = std::fs::write(path, value.to_string_pretty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            target_seconds: 0.05,
        };
        let t = bench("spin", &cfg, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(t.iters >= 5);
        assert!(t.mean_s > 0.0);
        assert!(t.min_s <= t.median_s);
        assert!(t.median_s <= t.p95_s + 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["d", "N0", "N1"]);
        t.row_str(&["8", "45", "25"]);
        t.row_str(&["128", "16513", "8446"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("16513"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_seconds(2.5e-9), "2.5 ns");
        assert_eq!(fmt_seconds(3.2e-5), "32.0 µs");
        assert_eq!(fmt_seconds(0.012), "12.00 ms");
        assert_eq!(fmt_seconds(2.0), "2.00 s");
        assert_eq!(fmt_mib(1024.0 * 1024.0 * 3.0), "3.0 MiB");
    }
}

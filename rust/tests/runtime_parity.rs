//! Integration tests: the three execution paths must agree —
//!
//! 1. pure-rust reference (`taylorshift::attention`)
//! 2. jax-AOT artifacts (jnp and Pallas lowerings) via the registry
//! 3. rust `XlaBuilder`-emitted executables
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise so
//! `cargo test` works on a fresh checkout).

use taylorshift::attention::{self, AttentionVariant};
use taylorshift::runtime::emitter::{self, EmitVariant};
use taylorshift::runtime::{literal, Registry, Runtime};
use taylorshift::tensor::Tensor;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    (
        Tensor::randn(&[n, d], seed),
        Tensor::randn(&[n, d], seed + 1),
        Tensor::randn(&[n, d], seed + 2),
    )
}

// The vendored `xla` crate is an offline stub whose
// `PjRtClient::compile` is gated off (no XLA runtime in this tree), so
// emitter *compilation* cannot execute by default. The real-runtime
// variants are behind the `xla-runtime` cargo feature
// (`cargo test --features xla-runtime` against real xla-rs bindings);
// the stub-backend tests below run unconditionally in CI.
#[cfg(feature = "xla-runtime")]
#[test]
fn emitter_matches_rust_reference_all_variants() {
    let rt = Runtime::cpu().unwrap();
    for (variant, evariant) in [
        (AttentionVariant::Direct, EmitVariant::TaylorDirect),
        (AttentionVariant::Efficient, EmitVariant::TaylorEfficient),
        (AttentionVariant::Softmax, EmitVariant::Softmax),
    ] {
        for (n, d) in [(64usize, 8usize), (128, 16), (96, 32)] {
            let (q, k, v) = qkv(n, d, 42 + n as u64);
            let exe = emitter::compile_attention(&rt, evariant, n, d, 1.0).unwrap();
            let got = emitter::run_attention(&exe, &q, &k, &v).unwrap();
            let want = attention::run_variant(variant, &q, &k, &v, 1.0);
            assert!(
                got.allclose(&want, 1e-3, 1e-4),
                "{variant} n={n} d={d}: max diff {}",
                got.max_abs_diff(&want)
            );
        }
    }
}

#[cfg(feature = "xla-runtime")]
#[test]
fn emitter_direct_equals_emitter_efficient() {
    let rt = Runtime::cpu().unwrap();
    let (n, d) = (160, 16);
    let (q, k, v) = qkv(n, d, 7);
    let dir = emitter::compile_attention(&rt, EmitVariant::TaylorDirect, n, d, 1.5).unwrap();
    let eff = emitter::compile_attention(&rt, EmitVariant::TaylorEfficient, n, d, 1.5).unwrap();
    let yd = emitter::run_attention(&dir, &q, &k, &v).unwrap();
    let ye = emitter::run_attention(&eff, &q, &k, &v).unwrap();
    assert!(
        yd.allclose(&ye, 1e-3, 1e-4),
        "max diff {}",
        yd.max_abs_diff(&ye)
    );
}

/// Stub-safe: building the HLO computation exercises the full emitter
/// graph construction (XlaBuilder works offline) without compiling.
#[test]
fn emitter_builds_all_variants_on_stub() {
    for evariant in [
        EmitVariant::Softmax,
        EmitVariant::TaylorDirect,
        EmitVariant::TaylorEfficient,
    ] {
        for (n, d) in [(64usize, 8usize), (128, 16)] {
            emitter::build_attention(evariant, n, d, 1.0)
                .unwrap_or_else(|e| panic!("{evariant:?} n={n} d={d}: {e}"));
        }
    }
}

/// On the stub backend, compilation must fail with an error (never
/// panic or pretend to succeed) — the behaviour CI exercises daily.
#[cfg(not(feature = "xla-runtime"))]
#[test]
fn stub_backend_gates_compilation() {
    let rt = Runtime::cpu().unwrap();
    let err = emitter::compile_attention(&rt, EmitVariant::TaylorDirect, 32, 8, 1.0);
    assert!(err.is_err(), "stub PjRtClient::compile must be gated off");
}

#[test]
fn aot_attention_artifacts_match_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let reg = Registry::open(rt, dir).unwrap();
    for (name, variant) in [
        ("attn_direct_n256_d16", AttentionVariant::Direct),
        ("attn_efficient_n256_d16", AttentionVariant::Efficient),
        ("attn_softmax_n256_d16", AttentionVariant::Softmax),
        // The Pallas-kernel lowerings must agree too — L1 parity.
        ("attn_pallas_direct_n256_d16", AttentionVariant::Direct),
        ("attn_pallas_efficient_n256_d16", AttentionVariant::Efficient),
        ("attn_pallas_softmax_n256_d16", AttentionVariant::Softmax),
    ] {
        let exe = reg.load(name).unwrap();
        let (q, k, v) = qkv(256, 16, 99);
        let outputs = exe
            .run(&[
                literal::tensor_to_literal(&q).unwrap(),
                literal::tensor_to_literal(&k).unwrap(),
                literal::tensor_to_literal(&v).unwrap(),
            ])
            .unwrap();
        let got = literal::literal_to_tensor(&outputs[0]).unwrap();
        let want = attention::run_variant(variant, &q, &k, &v, 1.0);
        assert!(
            got.allclose(&want, 1e-3, 1e-4),
            "{name}: max diff {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn aot_emitter_cross_parity() {
    // jax lowering and rust emitter produce the same function.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let reg = Registry::open(rt.clone(), dir).unwrap();
    let (q, k, v) = qkv(1024, 64, 123);
    let aot = reg.load("attn_efficient_n1024_d64").unwrap();
    let aot_out = aot
        .run(&[
            literal::tensor_to_literal(&q).unwrap(),
            literal::tensor_to_literal(&k).unwrap(),
            literal::tensor_to_literal(&v).unwrap(),
        ])
        .unwrap();
    let aot_y = literal::literal_to_tensor(&aot_out[0]).unwrap();
    let emitted =
        emitter::compile_attention(&rt, EmitVariant::TaylorEfficient, 1024, 64, 1.0).unwrap();
    let emit_y = emitter::run_attention(&emitted, &q, &k, &v).unwrap();
    assert!(
        aot_y.allclose(&emit_y, 1e-3, 1e-4),
        "max diff {}",
        aot_y.max_abs_diff(&emit_y)
    );
}

#[test]
fn registry_lists_and_loads_params() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let reg = Registry::open(rt, dir).unwrap();
    let names = reg.names();
    assert!(names.len() > 20, "expected many artifacts, got {}", names.len());
    // Infer artifact params load and match manifest shapes.
    let infer_names = reg.names_of_kind(taylorshift::runtime::ArtifactKind::Infer);
    assert!(!infer_names.is_empty());
    let name = &infer_names[0];
    let params = reg.load_params(name).unwrap();
    let exe = reg.load(name).unwrap();
    assert_eq!(params.len(), exe.io.params.len());
    for (t, spec) in params.iter().zip(&exe.io.params) {
        assert_eq!(t.shape(), &spec.shape[..]);
    }
}

#[test]
fn infer_artifact_runs_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let reg = Registry::open(rt, dir).unwrap();
    let name = "serve_efficient_infer_b1_n128";
    if !reg.contains(name) {
        eprintln!("skipping: {name} not in manifest");
        return;
    }
    let exe = reg.load(name).unwrap();
    let params = reg.load_params(name).unwrap();
    let mut inputs: Vec<xla::Literal> = params
        .iter()
        .map(|t| literal::tensor_to_literal(t).unwrap())
        .collect();
    let tokens: Vec<Vec<i32>> = vec![(0..128).map(|i| (i % 17) as i32).collect()];
    inputs.push(literal::tokens_to_literal(&tokens).unwrap());
    let outputs = exe.run(&inputs).unwrap();
    assert_eq!(outputs.len(), 1);
    let logits = literal::literal_to_tensor(&outputs[0]).unwrap();
    assert_eq!(logits.shape(), &[1, 10]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
}

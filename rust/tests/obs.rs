//! Integration tests for the observability subsystem: end-to-end trace
//! propagation through a forced mid-stream promotion, Prometheus
//! exposition round-tripping through the strict validator, concurrent
//! histogram recording, ring wraparound, and the automatic
//! flight-recorder dump on an induced eviction error.
//!
//! These run in their own test binary on purpose: the collector and
//! flight recorder are process-global, so assertions filter by trace
//! or session ID to stay independent of sibling tests.

use std::sync::atomic::Ordering;
use std::time::Duration;

use taylorshift::attention::selector::Selector;
use taylorshift::coordinator::engine::{BatchExecutor, Engine, EngineConfig};
use taylorshift::coordinator::metrics::LatencyHistogram;
use taylorshift::coordinator::request::RequestError;
use taylorshift::coordinator::router::Route;
use taylorshift::decode::DecodeConfig;
use taylorshift::obs::prometheus::validate_exposition;
use taylorshift::obs::recorder::{self, EventKind, EventRecord, Ring};
use taylorshift::obs::NO_LAYER;
use taylorshift::tensor::Tensor;
use taylorshift::util::json::Json;

/// Minimal prefill executor (decode tests never touch it).
struct NullExec;

impl BatchExecutor for NullExec {
    fn execute(&mut self, _route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(tokens.iter().map(|_| vec![0.0; 4]).collect())
    }

    fn batch_sizes(&self) -> &[usize] {
        &[1, 8]
    }
}

fn promotion_engine(d: usize, decode: DecodeConfig) -> Engine {
    Engine::start_with(
        EngineConfig {
            head_dim: d,
            // Crossover at N₀ = 8: sessions start on KV and promote
            // exactly when the prefix reaches 8 tokens.
            selector: Selector::calibrated(vec![(d, 8.0)]),
            decode,
            ..EngineConfig::default()
        },
        || Ok(NullExec),
    )
    .expect("engine starts")
}

/// Acceptance criterion: a forced mid-stream promotion leaves a span
/// trail of kv_step × 7 → promote → recurrent_step × 13, all carrying
/// one trace ID minted at stream open and returned on every response.
#[test]
fn promotion_trace_spans_carry_one_trace_end_to_end() {
    let d = 16usize;
    let decode = DecodeConfig {
        heads: 1,
        n_layers: 1,
        d_ff: 16,
        ..DecodeConfig::default()
    };
    let engine = promotion_engine(d, decode);
    let sid = engine.submit_stream().unwrap();
    let steps = 20usize;
    let mut trace = 0u64;
    for t in 0..steps {
        let token = Tensor::randn(&[1, d], 9_000 + t as u64);
        let resp = engine.decode_step(sid, token).unwrap();
        assert_eq!(resp.step, t + 1);
        assert_eq!(resp.promoted, t + 1 == 8, "promotion exactly at N₀");
        if t == 0 {
            trace = resp.trace;
            assert_ne!(trace, 0, "stream must carry a nonzero trace ID");
        } else {
            assert_eq!(resp.trace, trace, "one trace per stream");
        }
    }

    // The decode branch spans for this trace, in ring (= record) order.
    let events = recorder::global().snapshot();
    let branch_seq: Vec<&str> = events
        .iter()
        .filter(|e| e.kind == EventKind::Span && e.trace == trace)
        .filter(|e| {
            ["decode.kv_step", "decode.promote", "decode.recurrent_step"].contains(&e.name)
        })
        .map(|e| e.name)
        .collect();
    let mut want = vec!["decode.kv_step"; 7];
    want.push("decode.promote");
    want.extend(std::iter::repeat("decode.recurrent_step").take(13));
    assert_eq!(branch_seq, want, "span sequence across the KV→recurrent switch");

    // Per-layer block spans exist under the same trace, tagged layer 0.
    let block_spans = events
        .iter()
        .filter(|e| e.kind == EventKind::Span && e.trace == trace)
        .filter(|e| e.name == "model.block_step")
        .count();
    assert_eq!(block_spans, steps, "one block span per step per layer");
    assert!(events
        .iter()
        .filter(|e| e.trace == trace && e.name == "model.block_step")
        .all(|e| e.layer == Some(0)));

    // The promotion also landed as a lifecycle event on the ring.
    assert!(events
        .iter()
        .any(|e| e.kind == EventKind::Promote && e.trace == trace && e.a == sid.id()));
    // The handle returned at open already carries the same trace.
    assert_eq!(sid.trace(), trace);

    // The close-stream stats return the same trace for correlation.
    let stats = engine.close_stream(sid).unwrap();
    assert_eq!(stats.trace, trace);

    // Scrape after the stream: the exposition must round-trip through
    // the strict validator and carry per-layer and per-branch series.
    let text = engine.scrape();
    let stats = validate_exposition(&text).expect("exposition validates");
    assert!(stats.types > 10, "several families declared");
    assert!(stats.histograms > 5, "native histogram groups present");
    for needle in [
        "span_time_us",
        "layer=\"0\"",
        "branch=\"kv\"",
        "branch=\"recurrent\"",
        "taylorshift_decode_steps_total 20",
        "decode_lane_depth_total",
        "batch_occupancy_total",
    ] {
        assert!(text.contains(needle), "scrape missing {needle}:\n{text}");
    }
}

/// Satellite (c): multi-thread stress on `LatencyHistogram::record`
/// racing `export()`/`quantile()` readers — the final count is exact.
#[test]
fn histogram_concurrent_records_are_not_lost() {
    let h = LatencyHistogram::new();
    let threads = 8usize;
    let per_thread = 20_000usize;
    std::thread::scope(|scope| {
        for i in 0..threads {
            let h = &h;
            scope.spawn(move || {
                for j in 0..per_thread {
                    h.record(Duration::from_micros(1 + ((i * per_thread + j) % 1000) as u64));
                }
            });
        }
        // Concurrent readers must never block or see torn state.
        let h = &h;
        scope.spawn(move || {
            for _ in 0..100 {
                let snap = h.snapshot();
                assert!(snap.buckets.iter().sum::<u64>() <= (threads * per_thread) as u64);
                let _ = h.quantile(0.99);
            }
        });
    });
    assert_eq!(h.count(), (threads * per_thread) as u64);
    let snap = h.snapshot();
    assert_eq!(snap.count, (threads * per_thread) as u64);
    assert_eq!(snap.buckets.iter().sum::<u64>(), snap.count);
    assert!(h.quantile(0.5) >= Duration::from_micros(1));
}

/// Satellite (c): ring wraparound keeps exactly the newest `capacity`
/// events, in contiguous ascending sequence order.
#[test]
fn ring_wraparound_keeps_newest_events_in_order() {
    let ring = Ring::new(16);
    for i in 0..50u64 {
        ring.push(EventRecord {
            kind: EventKind::Enqueue,
            name_idx: 0,
            layer: NO_LAYER,
            trace: i,
            t_us: i,
            dur_us: 0,
            a: i,
            b: 0,
        });
    }
    assert_eq!(ring.pushed(), 50);
    let events = ring.snapshot();
    assert_eq!(events.len(), 16, "resident events == capacity");
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (35..=50).collect::<Vec<u64>>());
    for e in &events {
        assert_eq!(e.kind, EventKind::Enqueue);
        assert_eq!(e.trace + 1, e.seq, "payload stays paired with its seq");
    }
}

/// Satellite (f, engine side): an induced eviction error produces an
/// automatic flight-recorder dump — valid JSON naming the typed error
/// and bounded to events from before the error.
#[test]
fn eviction_error_surfaces_flight_recorder_dump() {
    let d = 16usize;
    let decode = DecodeConfig {
        heads: 1,
        n_layers: 1,
        d_ff: 16,
        max_sessions: 1,
        ..DecodeConfig::default()
    };
    let engine = promotion_engine(d, decode);
    assert!(engine.last_error_dump().is_none(), "no error yet");

    let s1 = engine.submit_stream().unwrap();
    engine.decode_step(s1, Tensor::randn(&[1, d], 1)).unwrap();
    // Opening a second stream under max_sessions=1 evicts s1.
    let s2 = engine.submit_stream().unwrap();
    let err = engine.decode_step(s1, Tensor::randn(&[1, d], 2)).unwrap_err();
    assert_eq!(err, RequestError::NeedsReprefill { id: s1.id() });

    let dump = engine.last_error_dump().expect("dump after typed error");
    let parsed = Json::parse(&dump).expect("dump is valid JSON");
    assert_eq!(parsed.get("error").and_then(Json::as_str), Some("needs_reprefill"));
    assert_eq!(
        parsed.get("subject").and_then(Json::as_f64),
        Some(s1.id() as f64)
    );
    let events = parsed.get("events").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty(), "dump carries the leading events");
    let boundary = parsed.get("seq").and_then(Json::as_f64).unwrap();
    assert!(events
        .iter()
        .all(|e| e.get("seq").and_then(Json::as_f64).unwrap_or(f64::MAX) <= boundary));

    // The eviction itself is on the ring as a lifecycle event.
    let ring = recorder::global().snapshot();
    assert!(ring.iter().any(|e| e.kind == EventKind::Evict && e.a == s1.id()));

    // The surviving stream still decodes.
    engine.decode_step(s2, Tensor::randn(&[1, d], 3)).unwrap();
    assert_eq!(engine.metrics().decode_misses.load(Ordering::Relaxed), 1);
}

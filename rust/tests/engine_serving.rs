//! Integration: the full serving stack over REAL artifacts — engine +
//! RegistryExecutor + adaptive variant selection.

use taylorshift::coordinator::batcher::BatchPolicy;
use taylorshift::coordinator::engine::{Engine, EngineConfig, RegistryExecutor};
use taylorshift::data::listops::ListOpsGen;
use taylorshift::data::TaskGenerator;
use taylorshift::util::rng::Pcg64;
use std::time::Duration;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn start_engine(buckets: Vec<usize>) -> Option<Engine> {
    let dir = artifacts_dir()?;
    let b = buckets.clone();
    let cfg = EngineConfig::builder()
        .buckets(buckets)
        .head_dim(16)
        .policy(BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
        })
        .queue_limit(128)
        .selector(taylorshift::attention::selector::Selector::analytical())
        .build()
        .expect("valid engine config");
    Some(
        Engine::start_with(cfg, move || {
            RegistryExecutor::new(dir, "serve", &b, &[1, 8])
        })
        .unwrap(),
    )
}

#[test]
fn serves_real_requests_with_adaptive_variants() {
    let Some(engine) = start_engine(vec![128, 256, 512, 1024]) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let gen_short = ListOpsGen { min_len: 20, max_len: 100, ..Default::default() };
    let gen_long = ListOpsGen { min_len: 400, max_len: 900, max_args: 8, ..Default::default() };
    let mut rng = Pcg64::new(1);

    let short = engine.infer(gen_short.generate(&mut rng).tokens).unwrap();
    assert_eq!(short.bucket, 128);
    assert_eq!(short.variant, taylorshift::attention::AttentionVariant::Direct);
    assert_eq!(short.logits.len(), 10);
    assert!(short.logits.iter().all(|x| x.is_finite()));

    let long = engine.infer(gen_long.generate(&mut rng).tokens).unwrap();
    assert!(long.bucket >= 512);
    assert_eq!(long.variant, taylorshift::attention::AttentionVariant::Efficient);
    assert!(long.logits.iter().all(|x| x.is_finite()));

    let m = engine.metrics();
    assert_eq!(m.completed.load(std::sync::atomic::Ordering::Relaxed), 2);
}

#[test]
fn direct_and_efficient_artifacts_agree_via_engine() {
    // Same request forced through both variants must produce the same
    // logits — the interchangeability claim at serving level.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rng = Pcg64::new(2);
    let gen = ListOpsGen { min_len: 60, max_len: 110, ..Default::default() };
    let tokens = gen.generate(&mut rng).tokens;

    let mut logits = Vec::new();
    for variant in [
        taylorshift::attention::AttentionVariant::Direct,
        taylorshift::attention::AttentionVariant::Efficient,
    ] {
        let d = dir.clone();
        let cfg = EngineConfig::builder()
            .buckets(vec![128])
            .head_dim(16)
            .policy(BatchPolicy {
                max_batch: 1,
                max_delay: Duration::ZERO,
            })
            .queue_limit(16)
            .forced_variant(variant)
            .selector(taylorshift::attention::selector::Selector::analytical())
            .build()
            .expect("valid engine config");
        let engine = Engine::start_with(cfg, move || {
            RegistryExecutor::new(d, "serve", &[128], &[1, 8])
        })
        .unwrap();
        logits.push(engine.infer(tokens.clone()).unwrap().logits);
    }
    for (a, b) in logits[0].iter().zip(&logits[1]) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn concurrent_load_is_batched() {
    let Some(engine) = start_engine(vec![128, 256]) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let gen = ListOpsGen { min_len: 20, max_len: 100, ..Default::default() };
    let mut rng = Pcg64::new(3);
    let rxs: Vec<_> = (0..24)
        .map(|_| engine.submit(gen.generate(&mut rng).tokens).unwrap())
        .collect();
    let mut max_batch = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "dynamic batching never fused requests");
    assert!(engine.metrics().mean_batch_occupancy() > 1.0);
}

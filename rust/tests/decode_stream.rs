//! Property tests for the streaming decode subsystem (`decode/` +
//! `model/`): incremental per-token decode must compute exactly the
//! same function as the batch implementations over the full prefix —
//! including across mid-stream KV→recurrent promotions, including the
//! whole multi-layer model — and the session store must respect its
//! memory budget.

use taylorshift::attention::selector::Selector;
use taylorshift::attention::{direct, efficient, run_variant, AttentionVariant};
use taylorshift::decode::{DecodeConfig, DecodeSession, KvCache, RecurrentState};
use taylorshift::model::{ModelConfig, ModelSession, SessionStore, StreamingModel};
use taylorshift::tensor::Tensor;
use taylorshift::testing::prop::{pair, run, Config, Gen};
use taylorshift::util::rng::Pcg64;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max)
}

fn prefix(t: &Tensor, n: usize, d: usize) -> Tensor {
    Tensor::new(&[n, d], t.data()[..n * d].to_vec())
}

#[test]
fn prop_recurrent_decode_matches_efficient_at_every_length() {
    run(
        Config::default().cases(24).seed(0xA11CE),
        pair(
            pair(Gen::usize_range(2, 40), Gen::usize_range(2, 12)),
            Gen::f64_range(0.5, 2.0),
        ),
        |&((n, d), tau)| {
            let tau = tau as f32;
            let seed = (n * 1000 + d) as u64;
            let q = Tensor::randn(&[n, d], seed);
            let k = Tensor::randn(&[n, d], seed + 1);
            let v = Tensor::randn(&[n, d], seed + 2);
            let mut state = RecurrentState::new(d, tau);
            for t in 0..n {
                let got = state.decode_step(q.row(t), k.row(t), v.row(t));
                let want = efficient::taylor_efficient(
                    &prefix(&q, t + 1, d),
                    &prefix(&k, t + 1, d),
                    &prefix(&v, t + 1, d),
                    tau,
                );
                if max_abs_diff(&got, want.row(t)) >= 1e-4 {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_kv_decode_matches_direct_at_every_length() {
    run(
        Config::default().cases(24).seed(0xCACE),
        pair(
            pair(Gen::usize_range(2, 40), Gen::usize_range(2, 12)),
            Gen::f64_range(0.5, 2.0),
        ),
        |&((n, d), tau)| {
            let tau = tau as f32;
            let seed = (n * 919 + d) as u64;
            let q = Tensor::randn(&[n, d], seed);
            let k = Tensor::randn(&[n, d], seed + 1);
            let v = Tensor::randn(&[n, d], seed + 2);
            let mut cache = KvCache::new(d, tau);
            for t in 0..n {
                let got = cache.decode_step(q.row(t), k.row(t), v.row(t));
                let want = direct::taylor_direct(
                    &prefix(&q, t + 1, d),
                    &prefix(&k, t + 1, d),
                    &prefix(&v, t + 1, d),
                    tau,
                    true,
                );
                if max_abs_diff(&got, want.row(t)) >= 1e-4 {
                    return false;
                }
            }
            true
        },
    );
}

/// Forced mid-stream promotion: a session starting on the KV branch and
/// promoted at a random crossover point must stay within 1e-4 of the
/// batch recompute of whichever branch served each step.
#[test]
fn prop_decode_is_continuous_across_promotion() {
    run(
        Config::default().cases(24).seed(0xBEEF),
        pair(
            pair(Gen::usize_range(4, 32), Gen::usize_range(2, 10)),
            pair(Gen::f64_range(0.5, 2.0), Gen::usize_range(2, 32)),
        ),
        |&((n, d), (tau, p))| {
            let tau = tau as f32;
            let p = p.min(n); // promotion point within the stream
            let seed = (n * 131 + d * 7 + p) as u64;
            let q = Tensor::randn(&[n, d], seed);
            let k = Tensor::randn(&[n, d], seed + 1);
            let v = Tensor::randn(&[n, d], seed + 2);
            let mut session = DecodeSession::new(1, d, tau, false);
            for t in 0..n {
                let row = |src: &Tensor| Tensor::new(&[1, d], src.row(t).to_vec());
                let r = session.step(&row(&q), &row(&k), &row(&v), Some(p as f64));
                if r.promoted != (t + 1 == p) {
                    return false;
                }
                let expect_branch = if t + 1 < p {
                    AttentionVariant::Direct
                } else {
                    AttentionVariant::Efficient
                };
                if r.branch != expect_branch {
                    return false;
                }
                let want = run_variant(
                    r.branch,
                    &prefix(&q, t + 1, d),
                    &prefix(&k, t + 1, d),
                    &prefix(&v, t + 1, d),
                    tau,
                );
                if max_abs_diff(&r.output, want.row(t)) >= 1e-4 {
                    return false;
                }
            }
            session.promoted_at() == Some(p)
        },
    );
}

/// The store never exceeds its session cap, and never exceeds its byte
/// budget while more than one session is resident (a single oversized
/// session is kept — the active stream must be able to make progress).
#[test]
fn prop_store_respects_budget_and_cap() {
    run(
        Config::default().cases(16).seed(0x5103),
        pair(
            pair(Gen::usize_range(2, 6), Gen::usize_range(1, 4)),
            Gen::usize_range(1, 24),
        ),
        |&((streams, max_sessions), steps_each)| {
            let d = 8usize;
            let cfg = DecodeConfig {
                heads: 1,
                n_layers: 1,
                d_ff: 16,
                // Tight: a few KV tokens' worth of state.
                max_session_bytes: 6 * 2 * d as u64 * 4,
                max_sessions,
                ..DecodeConfig::default()
            };
            let budget = cfg.max_session_bytes;
            // Forced Direct keeps sessions on the growing KV branch.
            let mut store = SessionStore::new(
                cfg,
                d,
                Selector::analytical(),
                Some(AttentionVariant::Direct),
            );
            for s in 0..streams as u64 {
                store.open(s);
                for t in 0..steps_each {
                    let token = Tensor::randn(&[1, d], s * 100 + t as u64);
                    // The session may itself have been evicted by a
                    // later open; a typed miss is a valid outcome here.
                    let _ = store.step(s, &token);
                    if store.len() > max_sessions {
                        return false;
                    }
                    if store.len() > 1 && store.resident_bytes() > budget {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// The acceptance-criteria property: for random (L ≤ 4, N ≤ 512, d,
/// tau), whole-model streaming matches the batch forward pass within
/// 1e-5 at every prefix length, with a strict subset of layers forced
/// to promote mid-stream (the rest stay on the KV branch throughout).
#[test]
fn prop_whole_model_streaming_matches_batch_forward() {
    run(
        Config::default().cases(8).seed(0xD00D),
        pair(
            pair(Gen::usize_range(1, 4), Gen::usize_range(8, 512)),
            pair(
                pair(Gen::usize_range(1, 2), Gen::usize_range(2, 8)),
                Gen::f64_range(0.5, 2.0),
            ),
        ),
        |&((n_layers, n), ((heads, head_dim), tau))| {
            let seed = (n_layers * 1_000_003 + n * 997 + heads * 131 + head_dim) as u64;
            let mut rng = Pcg64::new(seed);
            // A strict subset of layers promotes: `promoting` layers
            // (possibly zero, never all) cross at random points in
            // [2, n]; the rest never leave the KV branch.
            let promoting = rng.range_usize(0, n_layers);
            let promotions: Vec<Option<usize>> = (0..n_layers)
                .map(|l| (l < promoting).then(|| rng.range_usize(2, n + 1)))
                .collect();
            let cfg = ModelConfig {
                n_layers,
                heads,
                head_dim,
                d_ff: 2 * heads * head_dim,
                taus: (0..n_layers)
                    .map(|l| (tau * (1.0 + 0.07 * l as f64)) as f32)
                    .collect(),
                seed: seed ^ 0x9E37_79B9,
            };
            let model = StreamingModel::new(cfg);
            let dm = model.d_model();
            let x = Tensor::randn(&[n, dm], seed + 7);
            let batch = model.forward_batch(&x, &promotions);
            let thresholds = promotions.iter().map(|p| p.map(|v| v as f64)).collect();
            let mut session =
                ModelSession::with_thresholds(&model, &vec![false; n_layers], thresholds);
            for t in 0..n {
                let token = Tensor::new(&[1, dm], x.row(t).to_vec());
                let r = model.step(&mut session, &token);
                if r.len != t + 1 {
                    return false;
                }
                if max_abs_diff(&r.output, batch.row(t)) >= 1e-5 {
                    return false;
                }
                for (l, ls) in r.layers.iter().enumerate() {
                    if ls.promoted != (promotions[l] == Some(t + 1)) {
                        return false;
                    }
                }
            }
            session.promoted_at() == promotions
        },
    );
}

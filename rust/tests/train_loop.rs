//! Integration: the rust train driver over the AOT train-step artifact
//! actually learns (loss decreases) and checkpoints round-trip.

use taylorshift::data::listops::ListOpsGen;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::rng::Pcg64;

fn registry() -> Option<Registry> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Registry::open(Runtime::cpu().unwrap(), dir).unwrap())
}

fn listops_gen(seq_len: usize) -> ListOpsGen {
    ListOpsGen {
        min_len: 16,
        max_len: seq_len - 8,
        ..Default::default()
    }
}

#[test]
fn train_step_loss_decreases() {
    let Some(reg) = registry() else { return };
    let mut driver = TrainDriver::new(&reg, "listops_efficient_train_b16").unwrap();
    let gen = listops_gen(driver.seq_len());
    let mut rng = Pcg64::new(42);
    let report = driver.run(&gen, &mut rng, 30, |_| {}).unwrap();
    let head: f32 = report.history[..5].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    let tail = report.tail_loss(5);
    assert!(
        tail < head,
        "loss should decrease: head {head:.3} -> tail {tail:.3}"
    );
    assert!(report.steps_per_s > 0.0);
}

#[test]
fn fixed_batch_overfits() {
    // Repeating ONE batch must drive loss down hard — the sharpest
    // correctness signal for the full fwd+bwd+optimizer round-trip.
    let Some(reg) = registry() else { return };
    let mut driver = TrainDriver::new(&reg, "listops_efficient_train_b16").unwrap();
    let gen = listops_gen(driver.seq_len());
    let mut rng = Pcg64::new(7);
    let batch = taylorshift::data::batch::generate_batch(
        &gen,
        &mut rng,
        driver.batch_size(),
        driver.seq_len(),
    );
    let first = driver.step_on(&batch.tokens, &batch.labels).unwrap();
    let mut last = first;
    // The schedule has 50 warmup steps at low lr; run well past it.
    for _ in 0..120 {
        last = driver.step_on(&batch.tokens, &batch.labels).unwrap();
    }
    assert!(
        last.loss < 0.5 * first.loss,
        "overfit failed: {:.3} -> {:.3}",
        first.loss,
        last.loss
    );
    assert!(last.acc > first.acc || last.acc > 0.8);
}

#[test]
fn eval_artifact_consistent_with_training() {
    let Some(reg) = registry() else { return };
    let mut driver = TrainDriver::new(&reg, "listops_efficient_train_b16")
        .unwrap()
        .with_eval(&reg, "listops_efficient_eval_b32")
        .unwrap();
    let gen = listops_gen(driver.seq_len());
    let mut rng = Pcg64::new(3);
    let (loss, acc) = driver.evaluate(&gen, &mut rng, 2).unwrap();
    assert!(loss > 0.0 && loss < 20.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(reg) = registry() else { return };
    let mut driver = TrainDriver::new(&reg, "listops_efficient_train_b16")
        .unwrap()
        .with_eval(&reg, "listops_efficient_eval_b32")
        .unwrap();
    let gen = listops_gen(driver.seq_len());
    let mut rng = Pcg64::new(5);
    driver.run(&gen, &mut rng, 5, |_| {}).unwrap();

    let eval_batch =
        taylorshift::data::batch::generate_batch(&gen, &mut rng, 32, driver.seq_len());
    let (loss_before, _) = driver
        .evaluate_batch(&eval_batch.tokens, &eval_batch.labels)
        .unwrap();

    let dir = std::env::temp_dir().join(format!("ts_train_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.ckpt");
    driver.save_checkpoint(&path).unwrap();

    // More training changes the params...
    driver.run(&gen, &mut rng, 5, |_| {}).unwrap();
    let (loss_mid, _) = driver
        .evaluate_batch(&eval_batch.tokens, &eval_batch.labels)
        .unwrap();
    // ...and restoring brings the old eval back exactly.
    driver.load_checkpoint(&path).unwrap();
    let (loss_after, _) = driver
        .evaluate_batch(&eval_batch.tokens, &eval_batch.labels)
        .unwrap();
    assert!((loss_before - loss_after).abs() < 1e-5, "{loss_before} vs {loss_after}");
    // sanity: training in between did move the loss
    assert!((loss_mid - loss_before).abs() > 1e-7);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn softmax_baseline_trains_too() {
    let Some(reg) = registry() else { return };
    let mut driver = TrainDriver::new(&reg, "listops_softmax_train_b16").unwrap();
    let gen = listops_gen(driver.seq_len());
    let mut rng = Pcg64::new(11);
    let report = driver.run(&gen, &mut rng, 10, |_| {}).unwrap();
    assert!(report.final_loss.is_finite());
}

//! Integration tests for the session spill/restore tier: an
//! evict→spill→restore stream must be BIT-exact with an uninterrupted
//! stream at every prefix (including promotion happening before and
//! after the interruptions), corrupt/truncated spill files must fail
//! with typed errors plus a flight-recorder event, and closing a
//! spilled stream must report what is known and clean up its file.
//!
//! Own test binary on purpose: the flight recorder is process-global,
//! so assertions filter by session/trace ID, and every test uses its
//! own spill directory.

use std::sync::atomic::Ordering;

use taylorshift::attention::selector::Selector;
use taylorshift::coordinator::engine::{BatchExecutor, Engine, EngineConfig};
use taylorshift::coordinator::request::RequestError;
use taylorshift::coordinator::router::Route;
use taylorshift::decode::DecodeConfig;
use taylorshift::obs::prometheus::validate_exposition;
use taylorshift::obs::recorder::{self, ERR_SPILL_CORRUPT, EventKind};
use taylorshift::tensor::Tensor;

/// Minimal prefill executor (these tests only exercise decode).
struct NullExec;

impl BatchExecutor for NullExec {
    fn execute(&mut self, _route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(tokens.iter().map(|_| vec![0.0; 4]).collect())
    }

    fn batch_sizes(&self) -> &[usize] {
        &[1, 8]
    }
}

const D: usize = 16;

fn spill_dir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ts-spill-it-{}-{}", std::process::id(), name))
}

/// Engine with heads=1, 2 layers, crossover calibrated at N₀ = 8, and
/// an optional spill tier. `max_sessions: 1` plus a throwaway second
/// stream is how tests force an eviction at a chosen step.
fn engine_with(max_sessions: usize, spill: Option<std::path::PathBuf>) -> Engine {
    let mut b = EngineConfig::builder()
        .head_dim(D)
        .selector(Selector::calibrated(vec![(D, 8.0)]))
        .decode(DecodeConfig {
            heads: 1,
            n_layers: 2,
            d_ff: 16,
            max_sessions,
            ..DecodeConfig::default()
        });
    if let Some(dir) = spill {
        b = b.spill_enabled(true).spill_dir(dir);
    }
    Engine::start_with(b.build().expect("valid config"), || Ok(NullExec)).expect("engine starts")
}

fn token(t: usize) -> Tensor {
    Tensor::randn(&[1, D], 31_000 + t as u64)
}

/// The only `.spill` file in `dir` (panics if there isn't exactly one).
fn only_spill_file(dir: &std::path::Path) -> std::path::PathBuf {
    let files: Vec<_> = std::fs::read_dir(dir)
        .expect("spill dir exists")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "spill"))
        .collect();
    assert_eq!(files.len(), 1, "expected exactly one spill file: {files:?}");
    files.into_iter().next().unwrap()
}

/// Tentpole property: a stream that is spilled to disk and restored —
/// once on the KV branch (before promotion at step 8) and once on the
/// recurrent branch (after it) — produces BIT-identical outputs to a
/// never-interrupted stream at every prefix. f64 Taylor moments and
/// f32 KV rows both round-trip exactly, so this is `to_bits`
/// equality, not approximate.
#[test]
fn spilled_stream_is_bit_exact_with_uninterrupted_at_every_prefix() {
    let dir = spill_dir("bitexact");
    let reference = engine_with(256, None);
    let interrupted = engine_with(1, Some(dir.clone()));

    let r = reference.submit_stream().unwrap();
    let s = interrupted.submit_stream().unwrap();
    let steps = 16usize;
    // Spill the main stream before promotion (after step 4) and again
    // after promotion (after step 11) by touching a throwaway stream
    // under max_sessions = 1.
    for t in 0..steps {
        if t == 4 || t == 11 {
            let bump = interrupted.submit_stream().unwrap();
            interrupted.decode_step(bump, token(900 + t)).unwrap();
            assert!(
                interrupted.metrics().sessions_spilled.load(Ordering::Relaxed) >= 1,
                "main stream parked on disk at step {t}"
            );
        }
        let want = reference.decode_step(r, token(t)).unwrap();
        let got = interrupted.decode_step(s, token(t)).unwrap();
        assert_eq!(got.step, t + 1, "restored stream continues its prefix");
        assert_eq!(got.promoted, want.promoted, "promotion parity at step {t}");
        assert_eq!(got.output.len(), want.output.len());
        for (i, (a, b)) in want.output.iter().zip(&got.output).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "step {} output[{i}]: {a} vs {b}",
                t + 1
            );
        }
    }

    let m = interrupted.metrics();
    assert_eq!(
        m.sessions_restored.load(Ordering::Relaxed),
        2,
        "one restore per interruption"
    );
    assert_eq!(m.spill_failures.load(Ordering::Relaxed), 0);
    assert_eq!(m.decode_misses.load(Ordering::Relaxed), 0, "never NeedsReprefill");
    assert_eq!(m.restore_latency.count(), 2);

    // Both engines agree on the close-time summary of the main stream.
    let want = reference.close_stream(r).unwrap();
    let got = interrupted.close_stream(s).unwrap();
    assert_eq!(got.tokens, want.tokens);
    assert_eq!(got.branches, want.branches);
    assert_eq!(got.promoted_at, want.promoted_at);
    assert!(!got.evicted, "resident at close");

    // The spill/restore series scrape and validate.
    let text = interrupted.scrape();
    validate_exposition(&text).expect("exposition validates");
    for needle in [
        "taylorshift_sessions_spilled_total",
        "taylorshift_sessions_restored_total 2",
        "taylorshift_spill_failures_total 0",
        "taylorshift_restore_latency_us",
        "taylorshift_restored_state_bytes",
    ] {
        assert!(text.contains(needle), "scrape missing {needle}");
    }

    drop(interrupted);
    let _ = std::fs::remove_dir_all(dir);
}

/// A corrupt spill file fails restore with a typed error: the step
/// answers `NeedsReprefill`, `spill_failures` increments, the flight
/// recorder carries an `ERR_SPILL_CORRUPT` error event, and the
/// last-error dump names it.
#[test]
fn corrupt_spill_file_surfaces_typed_error_and_event() {
    let dir = spill_dir("corrupt");
    let engine = engine_with(1, Some(dir.clone()));

    let s1 = engine.submit_stream().unwrap();
    engine.decode_step(s1, token(0)).unwrap();
    let s2 = engine.submit_stream().unwrap();
    engine.decode_step(s2, token(1)).unwrap();

    // Flip the last payload byte of s1's spill file.
    let path = only_spill_file(&dir);
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();

    let err = engine.decode_step(s1, token(2)).unwrap_err();
    assert_eq!(err, RequestError::NeedsReprefill { id: s1.id() });
    let m = engine.metrics();
    assert_eq!(m.spill_failures.load(Ordering::Relaxed), 1);
    assert_eq!(m.sessions_restored.load(Ordering::Relaxed), 0);

    // The failed file was deleted — the id is now hard-evicted, and a
    // second touch is ordinary NeedsReprefill without a spill failure.
    assert!(!path.exists(), "corrupt file cleaned up");
    let err = engine.decode_step(s1, token(3)).unwrap_err();
    assert_eq!(err, RequestError::NeedsReprefill { id: s1.id() });
    assert_eq!(m.spill_failures.load(Ordering::Relaxed), 1, "counted once");

    // Flight recorder: an error event coded spill_corrupt for s1.
    let ring = recorder::global().snapshot();
    let hit = ring
        .iter()
        .any(|e| e.kind == EventKind::Error && e.a == ERR_SPILL_CORRUPT && e.b == s1.id());
    assert!(hit, "spill_corrupt error event on the ring");
    let dump = engine.last_error_dump().expect("typed error recorded");
    assert!(dump.contains("spill_corrupt"), "{dump}");

    drop(engine);
    let _ = std::fs::remove_dir_all(dir);
}

/// A truncated spill file (simulated partial write / disk-full) also
/// fails typed rather than panicking or restoring garbage.
#[test]
fn truncated_spill_file_fails_typed() {
    let dir = spill_dir("truncated");
    let engine = engine_with(1, Some(dir.clone()));

    let s1 = engine.submit_stream().unwrap();
    engine.decode_step(s1, token(0)).unwrap();
    let s2 = engine.submit_stream().unwrap();
    engine.decode_step(s2, token(1)).unwrap();

    let path = only_spill_file(&dir);
    let len = std::fs::metadata(&path).unwrap().len();
    let file = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    file.set_len(len / 2).unwrap();
    drop(file);

    let err = engine.decode_step(s1, token(2)).unwrap_err();
    assert_eq!(err, RequestError::NeedsReprefill { id: s1.id() });
    assert_eq!(engine.metrics().spill_failures.load(Ordering::Relaxed), 1);
    assert!(!path.exists(), "truncated file cleaned up");

    // The untouched stream still decodes fine.
    engine.decode_step(s2, token(3)).unwrap();

    drop(engine);
    let _ = std::fs::remove_dir_all(dir);
}

/// Satellite fix: closing an evicted-or-spilled stream succeeds with
/// the known summary instead of erroring, and removes the spill file.
#[test]
fn close_stream_on_spilled_session_reports_and_cleans_up() {
    let dir = spill_dir("close");
    let engine = engine_with(1, Some(dir.clone()));

    let s1 = engine.submit_stream().unwrap();
    for t in 0..3 {
        engine.decode_step(s1, token(t)).unwrap();
    }
    let s2 = engine.submit_stream().unwrap();
    engine.decode_step(s2, token(10)).unwrap();
    let path = only_spill_file(&dir);

    let stats = engine.close_stream(s1).unwrap();
    assert!(stats.evicted, "closed from the spilled state");
    assert_eq!(stats.tokens, 3, "tokens served before the spill");
    assert_eq!(stats.trace, s1.trace());
    assert!(!path.exists(), "close removed the spill file");
    assert_eq!(
        engine.metrics().spill_file_bytes.load(Ordering::Relaxed),
        0,
        "on-disk gauge back to zero"
    );

    // Closing it again is an ordinary unknown-session error.
    assert!(matches!(
        engine.close_stream(s1),
        Err(RequestError::UnknownSession { .. })
    ));

    drop(engine);
    let _ = std::fs::remove_dir_all(dir);
}

/// The one-release `u64` compatibility shim: raw ids stored from
/// `SessionHandle::id()` keep working across decode and close.
#[test]
fn raw_u64_session_ids_still_work() {
    let engine = engine_with(4, None);
    let handle = engine.submit_stream().unwrap();
    let raw: u64 = handle.id();
    let resp = engine.decode_step(raw, token(0)).unwrap();
    assert_eq!(resp.step, 1);
    let stats = engine.close_stream(raw).unwrap();
    assert_eq!(stats.tokens, 1);
    assert!(!stats.evicted);
}

//! Table 3 (reduced scale): accuracy of softmax / direct / efficient
//! transformers across the three tasks — the paper's core claim that
//! TaylorShift matches softmax attention's accuracy.
//!
//! Paper: 200 epochs on A100s. Here: `--steps` optimization steps per
//! model on CPU (defaults keep total runtime ~minutes); the comparison
//! of interest is BETWEEN columns at equal budget, not absolute SOTA.
//!
//! Run: `cargo run --release --example train_suite -- --steps 150`
//! Flags: --steps N --tasks listops,pixel --variants softmax,efficient
//!        --eval-batches K --seed S

use taylorshift::bench_support::Table;
use taylorshift::data::task_by_name;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::cli::Args;
use taylorshift::util::json::Json;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 150);
    let eval_batches = args.usize_or("eval-batches", 8);
    let seed = args.u64_or("seed", 42);
    let tasks: Vec<String> = args
        .get("tasks")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_else(|| vec!["listops".into(), "pixel".into(), "textbytes".into()]);
    let variants: Vec<String> = args
        .get("variants")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_else(|| vec!["softmax".into(), "direct".into(), "efficient".into()]);

    let reg = Registry::open(Runtime::cpu()?, args.str_or("artifacts-dir", "artifacts"))?;
    let mut rows: Vec<(String, Vec<f64>)> = variants
        .iter()
        .map(|v| (v.clone(), Vec::new()))
        .collect();
    let mut json_rows = Vec::new();

    for task in &tasks {
        println!("\n== task: {task} ({steps} steps/model) ==");
        for (vi, variant) in variants.iter().enumerate() {
            let train_name = format!("{task}_{variant}_train_b16");
            let eval_name = format!("{task}_{variant}_eval_b32");
            let mut driver = TrainDriver::new(&reg, &train_name)?.with_eval(&reg, &eval_name)?;
            let gen = task_by_name(task, driver.seq_len())
                .ok_or_else(|| anyhow::anyhow!("unknown task {task}"))?;
            let mut rng = Pcg64::new(seed);
            let t0 = std::time::Instant::now();
            let report = driver.run(&gen, &mut rng, steps, |s| {
                if s.step % 50 == 0 {
                    println!("  {variant:>9} step {:>4}  loss {:.4}  acc {:.3}", s.step, s.loss, s.acc);
                }
            })?;
            let (eval_loss, eval_acc) = driver.evaluate(&gen, &mut rng, eval_batches)?;
            println!(
                "  {variant:>9}: eval acc {:.3} (loss {:.3})  [{:.2} steps/s, {:.0}s]",
                eval_acc,
                eval_loss,
                report.steps_per_s,
                t0.elapsed().as_secs_f64()
            );
            rows[vi].1.push(eval_acc as f64 * 100.0);
            json_rows.push(Json::from_pairs(vec![
                ("task", Json::Str(task.clone())),
                ("variant", Json::Str(variant.clone())),
                ("eval_acc", Json::Num(eval_acc as f64)),
                ("eval_loss", Json::Num(eval_loss as f64)),
                ("steps", Json::Num(steps as f64)),
                ("steps_per_s", Json::Num(report.steps_per_s)),
            ]));
        }
    }

    println!("\n=== Table 3 (reduced scale): accuracy % ===\n");
    let mut headers: Vec<&str> = vec!["Model"];
    headers.extend(tasks.iter().map(|t| t.as_str()));
    headers.push("Average");
    let mut table = Table::new(&headers);
    for (variant, accs) in &rows {
        let avg = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
        let mut cells = vec![variant.clone()];
        cells.extend(accs.iter().map(|a| format!("{a:.1}")));
        cells.push(format!("{avg:.1}"));
        table.row(&cells);
    }
    table.print();
    taylorshift::bench_support::write_json(
        "table3_accuracy",
        &Json::from_pairs(vec![("rows", Json::Arr(json_rows))]),
    );
    println!("\nwrote bench_out/table3_accuracy.json");
    Ok(())
}

//! Empirical crossover calibration (the measurement behind Fig. 2 and
//! the `Selector::calibrated` policy).
//!
//! For each head dimension d, sweeps sequence length N, timing
//! rust-emitted PJRT executables of direct- vs efficient-TaylorShift,
//! locates the empirical intersection N̂₀, and compares it with the
//! analytical N₀ (Eq. 7) — reproducing the paper's §5.1 observation
//! that the measured crossover lands past the FLOP-equality point.
//! Writes `bench_out/crossover.json` consumable by the router.
//!
//! Run: `cargo run --release --example crossover_sweep -- --ds 8,16 --quick`

use taylorshift::analysis::transitions;
use taylorshift::attention::selector;
use taylorshift::bench_support::{bench, BenchConfig, Table};
use taylorshift::runtime::emitter::{self, EmitVariant};
use taylorshift::runtime::Runtime;
use taylorshift::tensor::Tensor;
use taylorshift::util::cli::Args;
use taylorshift::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let ds = args.usize_list("ds").unwrap_or(vec![8, 16, 32]);
    let quick = args.flag("quick");
    let rt = Runtime::cpu()?;

    let mut calibration: Vec<(usize, f64)> = Vec::new();
    let mut json_points = Vec::new();

    for &d in &ds {
        let n0 = transitions::n0(d as u64);
        // Sample N around the analytical crossover, log-spaced.
        let mut ns: Vec<usize> = Vec::new();
        let lo = (n0 * 0.25).max(64.0);
        let hi = n0 * (if quick { 3.0 } else { 6.0 });
        let points = if quick { 6 } else { 10 };
        for i in 0..points {
            let f = (lo.ln() + (hi.ln() - lo.ln()) * i as f64 / (points - 1) as f64).exp();
            ns.push((f / 32.0).round() as usize * 32); // align to 32
        }
        ns.dedup();

        let cfg = if quick {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 10,
                target_seconds: 0.3,
            }
        } else {
            BenchConfig::from_env()
        };

        let mut t_direct = Vec::new();
        let mut t_efficient = Vec::new();
        println!("\n== d = {d} (analytical N0 = {n0:.0}) ==");
        let mut table = Table::new(&["N", "direct", "efficient", "winner"]);
        for &n in &ns {
            let q = Tensor::randn(&[n, d], 1);
            let k = Tensor::randn(&[n, d], 2);
            let v = Tensor::randn(&[n, d], 3);
            let exe_d = emitter::compile_attention(&rt, EmitVariant::TaylorDirect, n, d, 1.0)?;
            let exe_e = emitter::compile_attention(&rt, EmitVariant::TaylorEfficient, n, d, 1.0)?;
            let td = bench(format!("direct_n{n}"), &cfg, || {
                emitter::run_attention(&exe_d, &q, &k, &v).unwrap();
            });
            let te = bench(format!("efficient_n{n}"), &cfg, || {
                emitter::run_attention(&exe_e, &q, &k, &v).unwrap();
            });
            t_direct.push(td.mean_s);
            t_efficient.push(te.mean_s);
            table.row(&[
                n.to_string(),
                taylorshift::bench_support::fmt_seconds(td.mean_s),
                taylorshift::bench_support::fmt_seconds(te.mean_s),
                if td.mean_s < te.mean_s { "direct" } else { "efficient" }.to_string(),
            ]);
        }
        table.print();

        match selector::calibrate_crossover(&ns, &t_direct, &t_efficient) {
            Some(cross) => {
                println!(
                    "empirical N̂0 = {cross:.0}  (analytical {n0:.0}, Δ = {:+.0}, paper's GPU rule Δ≈18d = {})",
                    cross - n0,
                    18 * d
                );
                calibration.push((d, cross));
                json_points.push(Json::from_pairs(vec![
                    ("d", Json::Num(d as f64)),
                    ("crossover", Json::Num(cross)),
                    ("analytical_n0", Json::Num(n0)),
                ]));
            }
            None => println!("no crossover in sampled range (extend the sweep)"),
        }
    }

    if !calibration.is_empty() {
        let sel = selector::Selector::calibrated(calibration.clone());
        println!("\ncalibrated selector: crossover(16) = {:.0}", sel.crossover(16));
        let out = Json::from_pairs(vec![("points", Json::Arr(json_points))]);
        taylorshift::bench_support::write_json("crossover", &out);
        println!("wrote bench_out/crossover.json");
    }
    Ok(())
}

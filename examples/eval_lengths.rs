//! Fig. 8: accuracy vs sequence length on ListOps.
//!
//! Trains one model on the standard length band, then evaluates it on
//! sequences of controlled lengths inside and beyond the training
//! distribution — the paper observes gradual decay in-distribution and
//! a sharp drop out-of-distribution. Also dumps the Fig. 7 QK^T
//! statistics proxy (per-head temperature values of the trained model).
//!
//! Run: `cargo run --release --example eval_lengths -- --steps 200`

use taylorshift::bench_support::Table;
use taylorshift::data::batch::{collate, Batch};
use taylorshift::data::listops::ListOpsGen;
use taylorshift::data::TaskGenerator;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

fn batch_of_length(
    gen_tpl: &ListOpsGen,
    rng: &mut Pcg64,
    len: usize,
    count: usize,
    pad_to: usize,
) -> Batch {
    let gen = ListOpsGen {
        min_len: len.saturating_sub(len / 5).max(8),
        max_len: len,
        ..gen_tpl.clone()
    };
    let examples: Vec<_> = (0..count).map(|_| gen.generate(rng)).collect();
    collate(&examples, pad_to, 0)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 200);
    let seed = args.u64_or("seed", 42);

    let reg = Registry::open(Runtime::cpu()?, args.str_or("artifacts-dir", "artifacts"))?;
    let mut driver = TrainDriver::new(&reg, "listops_efficient_train_b16")?
        .with_eval(&reg, "listops_efficient_eval_b32")?;
    let n_max = driver.seq_len();

    // Training distribution: lengths 16..(N-8) — mirror of train_listops.
    let train_gen = ListOpsGen {
        min_len: 16,
        max_len: n_max - 8,
        ..Default::default()
    };
    let mut rng = Pcg64::new(seed);
    println!("training {steps} steps on lengths {}..{} ...", 16, n_max - 8);
    let report = driver.run(&train_gen, &mut rng, steps, |s| {
        if s.step % 50 == 0 {
            println!("  step {:>4} loss {:.3} acc {:.3}", s.step, s.loss, s.acc);
        }
    })?;
    println!("trained: final acc {:.3}\n", report.final_acc);

    // Evaluate at controlled lengths (padded to the artifact's N).
    let mut table = Table::new(&["target len", "in-dist?", "accuracy"]);
    let lengths = [24usize, 48, 96, 144, 192, 224, 248];
    let trained_band = 16..=(n_max - 8);
    for &len in &lengths {
        if len > n_max {
            continue;
        }
        let mut acc_sum = 0.0f32;
        let reps = 6;
        for _ in 0..reps {
            let b = batch_of_length(&train_gen, &mut rng, len, 32, n_max);
            let (_, acc) = driver.evaluate_batch(&b.tokens, &b.labels)?;
            acc_sum += acc;
        }
        table.row(&[
            len.to_string(),
            if trained_band.contains(&len) { "yes" } else { "OOD" }.to_string(),
            format!("{:.3}", acc_sum / reps as f32),
        ]);
    }
    println!("=== Fig. 8 (reduced scale): accuracy vs sequence length ===\n");
    table.print();

    // Fig. 7 proxy: learned per-head temperatures bound |QK^T| post-norm.
    let names = driver.param_names();
    let params = driver.params()?;
    println!("\nlearned attention temperatures τ (bound |QKᵀ| ≤ τ, Fig. 7 support):");
    for (name, t) in names.iter().zip(&params) {
        if name.ends_with("/tau") {
            let vals: Vec<String> = t.data().iter().map(|x| format!("{x:.2}")).collect();
            println!("  {name}: [{}]", vals.join(", "));
        }
    }
    Ok(())
}

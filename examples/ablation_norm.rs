//! Table 4 + Fig. 4: the normalization ablation.
//!
//! Trains the pixel-task model with direct/efficient TaylorShift at the
//! three normalization stages (plain / +input norm / +output norm) and
//! reports accuracy — expecting the efficient+plain combination to be
//! numerically unstable (the paper's motivating failure). With
//! `--divergence`, additionally demonstrates the Table 1 intermediate
//! blow-up directly on the unnormalized pipeline.
//!
//! Run: `cargo run --release --example ablation_norm -- --steps 120`

use taylorshift::attention::efficient;
use taylorshift::bench_support::Table;
use taylorshift::data::task_by_name;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::tensor::Tensor;
use taylorshift::train::TrainDriver;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 120);
    let seed = args.u64_or("seed", 42);

    if args.flag("divergence") {
        divergence_demo();
    }

    let reg = Registry::open(Runtime::cpu()?, args.str_or("artifacts-dir", "artifacts"))?;
    let stages = ["plain", "input", "full"];
    let variants = ["direct", "efficient"];
    let mut table = Table::new(&["Model", "direct", "efficient"]);
    let mut rows: Vec<Vec<String>> = stages
        .iter()
        .map(|s| {
            vec![match *s {
                "plain" => "Plain impl.".to_string(),
                "input" => "impl. + norm.".to_string(),
                _ => "impl. + norm. + output norm.".to_string(),
            }]
        })
        .collect();

    for (si, stage) in stages.iter().enumerate() {
        for variant in variants {
            let name = format!("pixel_{variant}_{stage}_train_b16");
            print!("training {name} ... ");
            let mut driver = TrainDriver::new(&reg, &name)?;
            let gen = task_by_name("pixel", driver.seq_len()).unwrap();
            let mut rng = Pcg64::new(seed);
            let mut diverged = false;
            let mut final_acc = 0.0f32;
            for _ in 0..steps {
                let batch = taylorshift::data::batch::generate_batch(
                    &gen,
                    &mut rng,
                    driver.batch_size(),
                    driver.seq_len(),
                );
                match driver.step_on(&batch.tokens, &batch.labels) {
                    Ok(s) if s.loss.is_finite() => final_acc = s.acc,
                    _ => {
                        diverged = true;
                        break;
                    }
                }
            }
            // Use a small rolling eval on fresh batches for the cell.
            let cell = if diverged {
                "diverged (NaN)".to_string()
            } else {
                let mut accs = Vec::new();
                for _ in 0..4 {
                    let batch = taylorshift::data::batch::generate_batch(
                        &gen,
                        &mut rng,
                        driver.batch_size(),
                        driver.seq_len(),
                    );
                    // train-step acc on fresh data ~ streaming eval
                    match driver.step_on(&batch.tokens, &batch.labels) {
                        Ok(s) => accs.push(s.acc),
                        Err(_) => {
                            diverged = true;
                            break;
                        }
                    }
                }
                if diverged {
                    "diverged (NaN)".to_string()
                } else {
                    let mean = accs.iter().sum::<f32>() / accs.len().max(1) as f32;
                    format!("{:.1}", mean.max(final_acc) * 100.0)
                }
            };
            println!("{cell}");
            rows[si].push(cell);
        }
    }
    for row in rows {
        table.row(&row);
    }
    println!("\n=== Table 4 (reduced scale): CIFAR-pixel substitute accuracy ===\n");
    table.print();
    Ok(())
}

/// Fig. 4 / Table 1 evidence: intermediate magnitudes of the
/// unnormalized efficient pipeline grow with N until f32 saturates,
/// while the normalized Algorithm 1 stays O(1).
fn divergence_demo() {
    println!("=== divergence demo: unnormalized intermediate growth ===\n");
    let d = 16;
    let mut t = Table::new(&["N", "|A_mod| (unnorm)", "|Y_denom| (unnorm)", "|Y| normalized"]);
    for n in [256usize, 1024, 4096, 16384] {
        let q = Tensor::rand_unit_rows(n, d, 1);
        let k = Tensor::rand_unit_rows(n, d, 2);
        let v = Tensor::rand_unit_rows(n, d, 3);
        let (a_mod, _, _, y_denom, _) = efficient::intermediate_sizes(&q, &k, &v);
        let y_norm = efficient::taylor_efficient(&q, &k, &v, 1.0).mean_row_norm();
        t.row(&[
            n.to_string(),
            format!("{a_mod:.1}"),
            format!("{y_denom:.1}"),
            format!("{y_norm:.3}"),
        ]);
    }
    t.print();
    println!("(unnormalized magnitudes grow ~N — in fp16 this overflows at N≈4k;\n normalized output stays O(1) regardless — Section 3.3)\n");
}

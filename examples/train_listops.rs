//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): train a TaylorShift
//! transformer on procedurally-generated Long ListOps through the full
//! three-layer stack — rust data generation + loop driving an AOT HLO
//! train step whose attention lowered from the efficient-TaylorShift
//! formulation — logging the loss curve, evaluating held-out accuracy,
//! and writing a checkpoint.
//!
//! Run: `cargo run --release --example train_listops -- --steps 300`
//! Flags: --artifact NAME --steps N --seed S --eval-batches K
//!        --out ckpt.bin --curve loss_curve.csv

use taylorshift::data::listops::ListOpsGen;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let artifact = args.str_or("artifact", "listops_efficient_train_b16");
    let eval_artifact = artifact.replace("_train_b16", "_eval_b32");
    let steps = args.usize_or("steps", 300);
    let seed = args.u64_or("seed", 42);
    let eval_batches = args.usize_or("eval-batches", 8);

    let reg = Registry::open(Runtime::cpu()?, args.str_or("artifacts-dir", "artifacts"))?;
    let mut driver = TrainDriver::new(&reg, artifact)?.with_eval(&reg, &eval_artifact)?;
    let gen = ListOpsGen {
        min_len: 16,
        max_len: driver.seq_len() - 8,
        ..Default::default()
    };
    let mut rng = Pcg64::new(seed);

    println!(
        "e2e: training {artifact} — {} params over {steps} steps (B={}, N={})",
        reg.entry(artifact)?
            .get("num_params")
            .and_then(|x| x.as_usize())
            .unwrap_or(0),
        driver.batch_size(),
        driver.seq_len()
    );

    let mut curve = String::from("step,loss,acc\n");
    let report = driver.run(&gen, &mut rng, steps, |s| {
        curve.push_str(&format!("{},{:.6},{:.4}\n", s.step, s.loss, s.acc));
        if s.step % 20 == 0 || s.step == 1 {
            println!(
                "step {:>5}  loss {:.4}  acc {:.3}  ({:.0} ms/step)",
                s.step,
                s.loss,
                s.acc,
                s.step_time_s * 1e3
            );
        }
    })?;

    let (eval_loss, eval_acc) = driver.evaluate(&gen, &mut rng, eval_batches)?;
    println!("\n=== E2E summary ===");
    println!("loss: {:.4} (first) → {:.4} (tail-20 mean)", report.history[0].loss, report.tail_loss(20));
    println!("held-out: loss {eval_loss:.4}, acc {eval_acc:.3} ({} batches × 32)", eval_batches);
    println!("throughput: {:.2} steps/s  ({:.1} seq/s)", report.steps_per_s, report.steps_per_s * driver.batch_size() as f64);

    let curve_path = args.str_or("curve", "bench_out/listops_loss_curve.csv");
    if let Some(parent) = std::path::Path::new(curve_path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(curve_path, curve)?;
    println!("loss curve → {curve_path}");

    let ckpt = args.str_or("out", "bench_out/listops_model.ckpt");
    driver.save_checkpoint(std::path::Path::new(ckpt))?;
    println!("checkpoint → {ckpt}");

    anyhow::ensure!(
        report.tail_loss(20) < report.history[0].loss,
        "loss did not decrease"
    );
    Ok(())
}

//! Quickstart: the paper's mechanism in 60 seconds.
//!
//! 1. Direct- and efficient-TaylorShift compute the SAME function —
//!    shown with the pure-rust reference implementations.
//! 2. The analytical crossover points N₀/N₁ (Table 2) tell you which
//!    to run at each sequence length.
//! 3. The rust-native XlaBuilder emitter compiles a specialized PJRT
//!    executable at runtime and matches the reference numerics.
//!
//! Run: `cargo run --release --example quickstart`

use taylorshift::analysis::transitions;
use taylorshift::attention::{self, selector::Selector, AttentionVariant};
use taylorshift::bench_support::Table;
use taylorshift::runtime::emitter::{self, EmitVariant};
use taylorshift::runtime::Runtime;
use taylorshift::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    println!("== 1. Two implementations, one function ==\n");
    let (n, d) = (256, 16);
    let q = Tensor::randn(&[n, d], 1);
    let k = Tensor::randn(&[n, d], 2);
    let v = Tensor::randn(&[n, d], 3);
    let y_direct = attention::direct::taylor_direct(&q, &k, &v, 1.0, true);
    let y_efficient = attention::efficient::taylor_efficient(&q, &k, &v, 1.0);
    println!(
        "direct vs efficient @ N={n}, d={d}: max |Δ| = {:.2e}  (same function ✓)",
        y_direct.max_abs_diff(&y_efficient)
    );

    println!("\n== 2. When to shift (and back) — Table 2 ==\n");
    let mut t = Table::new(&["d", "N0 (speed)", "N1 (memory)"]);
    for (d, n0, n1) in transitions::table2() {
        t.row(&[d.to_string(), n0.to_string(), n1.to_string()]);
    }
    t.print();
    let selector = Selector::analytical();
    for probe in [128usize, 1024, 8192] {
        println!(
            "  N={probe:>5}, d=16  →  {}",
            selector.select(probe, 16)
        );
    }

    println!("\n== 3. Runtime shape specialization via XlaBuilder ==\n");
    let rt = Runtime::cpu()?;
    let exe = emitter::compile_attention(&rt, EmitVariant::TaylorEfficient, n, d, 1.0)?;
    let y_xla = emitter::run_attention(&exe, &q, &k, &v)?;
    println!(
        "XLA-emitted efficient vs rust reference: max |Δ| = {:.2e}  ✓",
        y_xla.max_abs_diff(&y_efficient)
    );
    let selected = selector.select(n, d);
    assert_eq!(selected, AttentionVariant::Direct); // 256 < N0(16)≈271
    println!("\nAt N={n} the selector picks '{selected}' — shifting back for short inputs.");
    Ok(())
}

//! Scrape self-check: drive a small prefill + streaming-decode load,
//! render the Prometheus exposition via `Engine::scrape()`, and fail
//! (non-zero exit) unless it round-trips through the strict validator
//! with the per-layer and per-branch series present. CI runs this and
//! uploads the exposition next to the bench JSON artifacts.
//!
//! Run: `cargo run --release --example scrape_check -- --out SCRAPE_sample.txt`
//! Flags: --out PATH (write the exposition there) --decode-tokens T

use taylorshift::attention::selector::Selector;
use taylorshift::coordinator::engine::{BatchExecutor, Engine, EngineConfig};
use taylorshift::coordinator::router::Route;
use taylorshift::obs::prometheus::validate_exposition;
use taylorshift::tensor::Tensor;
use taylorshift::util::cli::Args;

/// Prefill stand-in so the check runs without compiled artifacts.
struct NullPrefill;

impl BatchExecutor for NullPrefill {
    fn execute(&mut self, _route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(tokens.iter().map(|_| vec![0.0; 10]).collect())
    }

    fn batch_sizes(&self) -> &[usize] {
        &[1, 8]
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let decode_tokens = args.usize_or("decode-tokens", 24);

    let spill_dir =
        std::env::temp_dir().join(format!("taylorshift-scrape-spill-{}", std::process::id()));
    let cfg = EngineConfig::builder()
        // Calibrated crossover at N₀ = 8 so the stream below exercises
        // both decode branches and the promotion inside one short run.
        .selector(Selector::calibrated(vec![(16, 8.0)]))
        // One resident session + the spill tier: opening a second
        // stream parks the first on disk, touching it restores it —
        // so the spill/restore series below are nonzero.
        .max_sessions(1)
        .spill_enabled(true)
        .spill_dir(spill_dir.clone())
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let d_model = cfg.decode.heads * cfg.head_dim;
    let engine = Engine::start_with(cfg, || Ok(NullPrefill))?;

    // A little prefill traffic (batcher + exec spans)...
    for i in 0..12u64 {
        let len = 64 + (i as usize % 3) * 100;
        let tokens: Vec<i32> = (0..len as i32).collect();
        engine.infer(tokens).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    // ...and one stream across the crossover (KV, promote, recurrent).
    let sid = engine.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    for t in 0..decode_tokens {
        let token = Tensor::randn(&[1, d_model], 77 + t as u64);
        engine
            .decode_step(sid, token)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    engine.close_stream(sid).map_err(|e| anyhow::anyhow!("{e}"))?;

    // Spill round trip: `b` evicts `a` to disk, touching `a` restores
    // it, so the spill counters and restore histogram are populated.
    let a = engine.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    engine
        .decode_step(a, Tensor::randn(&[1, d_model], 501))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let b = engine.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    engine
        .decode_step(b, Tensor::randn(&[1, d_model], 502))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    engine
        .decode_step(a, Tensor::randn(&[1, d_model], 503))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    engine.close_stream(a).map_err(|e| anyhow::anyhow!("{e}"))?;
    engine.close_stream(b).map_err(|e| anyhow::anyhow!("{e}"))?;

    let text = engine.scrape();
    let stats = validate_exposition(&text)
        .map_err(|e| anyhow::anyhow!("exposition failed validation: {e}"))?;
    println!(
        "exposition OK: {} TYPE families, {} series, {} histogram groups",
        stats.types, stats.series, stats.histograms
    );

    // The series the dashboards depend on must actually be present.
    for needle in [
        "taylorshift_requests_completed_total",
        "taylorshift_decode_steps_total",
        "taylorshift_batch_occupancy_total",
        "taylorshift_decode_lane_depth_total",
        "taylorshift_span_time_us_bucket",
        "span=\"engine.exec_batch\"",
        "layer=\"0\"",
        "layer=\"1\"",
        "branch=\"kv\"",
        "branch=\"recurrent\"",
        // `b` opening spills `a`; restoring `a` spills `b` in turn.
        "taylorshift_sessions_spilled_total 2",
        "taylorshift_sessions_restored_total 1",
        "taylorshift_spill_failures_total 0",
        "taylorshift_restore_latency_us",
    ] {
        if !text.contains(needle) {
            anyhow::bail!("exposition is missing expected series `{needle}`");
        }
    }

    if let Some(path) = args.get("out") {
        std::fs::write(path, &text)?;
        println!("wrote exposition sample to {path}");
    }
    let _ = std::fs::remove_dir_all(spill_dir);
    Ok(())
}

//! Scrape self-check: drive a small prefill + streaming-decode load,
//! render the Prometheus exposition via `Engine::scrape()`, and fail
//! (non-zero exit) unless it round-trips through the strict validator
//! with the per-layer and per-branch series present. CI runs this and
//! uploads the exposition next to the bench JSON artifacts.
//!
//! Run: `cargo run --release --example scrape_check -- --out SCRAPE_sample.txt`
//! Flags: --out PATH (write the exposition there) --decode-tokens T

use taylorshift::attention::selector::Selector;
use taylorshift::coordinator::engine::{BatchExecutor, Engine, EngineConfig};
use taylorshift::coordinator::router::Route;
use taylorshift::obs::prometheus::validate_exposition;
use taylorshift::tensor::Tensor;
use taylorshift::util::cli::Args;

/// Prefill stand-in so the check runs without compiled artifacts.
struct NullPrefill;

impl BatchExecutor for NullPrefill {
    fn execute(&mut self, _route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(tokens.iter().map(|_| vec![0.0; 10]).collect())
    }

    fn batch_sizes(&self) -> &[usize] {
        &[1, 8]
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let decode_tokens = args.usize_or("decode-tokens", 24);

    let cfg = EngineConfig {
        // Calibrated crossover at N₀ = 8 so the stream below exercises
        // both decode branches and the promotion inside one short run.
        selector: Selector::calibrated(vec![(16, 8.0)]),
        ..EngineConfig::default()
    };
    let d_model = cfg.decode.heads * cfg.head_dim;
    let engine = Engine::start_with(cfg, || Ok(NullPrefill))?;

    // A little prefill traffic (batcher + exec spans)...
    for i in 0..12u64 {
        let len = 64 + (i as usize % 3) * 100;
        let tokens: Vec<i32> = (0..len as i32).collect();
        engine.infer(tokens).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    // ...and one stream across the crossover (KV, promote, recurrent).
    let sid = engine.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    for t in 0..decode_tokens {
        let token = Tensor::randn(&[1, d_model], 77 + t as u64);
        engine
            .decode_step(sid, token)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    engine.close_stream(sid).map_err(|e| anyhow::anyhow!("{e}"))?;

    let text = engine.scrape();
    let stats = validate_exposition(&text)
        .map_err(|e| anyhow::anyhow!("exposition failed validation: {e}"))?;
    println!(
        "exposition OK: {} TYPE families, {} series, {} histogram groups",
        stats.types, stats.series, stats.histograms
    );

    // The series the dashboards depend on must actually be present.
    for needle in [
        "taylorshift_requests_completed_total",
        "taylorshift_decode_steps_total",
        "taylorshift_batch_occupancy_total",
        "taylorshift_decode_lane_depth_total",
        "taylorshift_span_time_us_bucket",
        "span=\"engine.exec_batch\"",
        "layer=\"0\"",
        "layer=\"1\"",
        "branch=\"kv\"",
        "branch=\"recurrent\"",
    ] {
        if !text.contains(needle) {
            anyhow::bail!("exposition is missing expected series `{needle}`");
        }
    }

    if let Some(path) = args.get("out") {
        std::fs::write(path, &text)?;
        println!("wrote exposition sample to {path}");
    }
    Ok(())
}

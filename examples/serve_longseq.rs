//! Serving demo: the coordinator under a mixed-length synthetic load.
//!
//! Shows the paper's "(and Back)" live: short requests route to the
//! direct-TaylorShift executables, long ones to efficient, per the
//! analytical crossover; the dynamic batcher fuses same-bucket
//! arrivals. Reports latency percentiles, throughput, batch occupancy
//! and the per-variant split.
//!
//! Also demos the **whole-model streaming decode** path: a session fed
//! one token embedding at a time threads it through every transformer
//! block; each layer starts on the KV-cache branch and is promoted to
//! the O(1) recurrent state when its prefix crosses N₀ — the crossover
//! applied at decode time, per layer.
//!
//! The engine is observable while it runs: every 256 decode steps a
//! scrape snapshot (selected Prometheus series from `Engine::scrape`)
//! is printed, `--scrape-out PATH` writes the full exposition at the
//! end, and an induced session eviction at the end shows the
//! flight-recorder dump that accompanies every typed engine error.
//!
//! The finale demos the session **spill/restore tier**: with spill
//! enabled, an induced eviction parks a stream's state in a spill file
//! and the next decode step restores it transparently — no
//! `NeedsReprefill`. `--spill-out PATH` writes the spill/restore
//! counters as JSON (CI uploads them next to the bench artifacts).
//!
//! Run: `cargo run --release --example serve_longseq -- --requests 200`
//! Flags: --requests N --concurrency C --variant auto|direct|efficient
//!        --max-delay-ms D --decode-tokens T --seed S --scrape-out PATH
//!        --spill-out PATH

use std::time::{Duration, Instant};
use taylorshift::coordinator::batcher::BatchPolicy;
use taylorshift::coordinator::engine::{BatchExecutor, Engine, EngineConfig, RegistryExecutor};
use taylorshift::coordinator::router::Route;
use taylorshift::data::listops::ListOpsGen;
use taylorshift::data::TaskGenerator;
use taylorshift::tensor::Tensor;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

/// Fallback prefill executor so the decode demo runs on a checkout
/// without `make artifacts` (returns zero logits).
struct NullPrefill {
    sizes: Vec<usize>,
}

impl BatchExecutor for NullPrefill {
    fn execute(&mut self, _route: Route, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(tokens.iter().map(|_| vec![0.0; 10]).collect())
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 200);
    let concurrency = args.usize_or("concurrency", 16);
    let seed = args.u64_or("seed", 1);
    let buckets = vec![128usize, 256, 512, 1024];

    let mut cfg = EngineConfig::builder()
        .buckets(buckets.clone())
        .head_dim(16)
        .policy(BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_micros((args.f64_or("max-delay-ms", 2.0) * 1000.0) as u64),
        })
        .queue_limit(512)
        .selector(taylorshift::attention::selector::Selector::analytical())
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(v) = args.get("variant") {
        if v != "auto" {
            cfg.forced_variant = taylorshift::attention::AttentionVariant::parse(v);
        }
    }
    // Use a machine-measured crossover if crossover_sweep produced one.
    if let Some(cal) = args.get("calibration") {
        cfg.selector = taylorshift::attention::selector::Selector::from_json_file(
            std::path::Path::new(cal),
        )?;
    }

    let dir = args.str_or("artifacts-dir", "artifacts").to_string();
    let heads = cfg.decode.heads;
    let head_dim = cfg.head_dim;
    println!("compiling serving executables (one per bucket × variant × batch)...");
    let t0 = Instant::now();
    let engine = match Engine::start_with(cfg.clone(), move || {
        RegistryExecutor::new(&dir, "serve", &[128, 256, 512, 1024], &[1, 8])
    }) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); using a null prefill executor");
            Engine::start_with(cfg, || Ok(NullPrefill { sizes: vec![1, 8] }))?
        }
    };
    println!("engine ready in {:.1}s", t0.elapsed().as_secs_f64());

    // Mixed-length load: bursts of short queries + a long-document tail,
    // issued from `concurrency` client threads.
    let gen_short = ListOpsGen { min_len: 20, max_len: 220, ..Default::default() };
    let gen_long = ListOpsGen { min_len: 300, max_len: 1000, max_args: 8, ..Default::default() };
    let mut rng = Pcg64::new(seed);
    let workloads: Vec<Vec<i32>> = (0..requests)
        .map(|_| {
            if rng.bernoulli(0.7) {
                gen_short.generate(&mut rng).tokens
            } else {
                gen_long.generate(&mut rng).tokens
            }
        })
        .collect();

    let engine = std::sync::Arc::new(engine);
    let t0 = Instant::now();
    let chunk = workloads.len().div_ceil(concurrency);
    std::thread::scope(|scope| {
        for part in workloads.chunks(chunk) {
            let engine = std::sync::Arc::clone(&engine);
            let part: Vec<Vec<i32>> = part.to_vec();
            scope.spawn(move || {
                for tokens in part {
                    match engine.infer(tokens) {
                        Ok(_) => {}
                        Err(e) => eprintln!("request failed: {e}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== load complete: {requests} requests in {wall:.2}s ({:.1} req/s) ===\n", requests as f64 / wall);

    // --- whole-model streaming decode: crossover applied per layer ---
    let decode_tokens = args.usize_or("decode-tokens", 1024);
    let d_model = heads * head_dim;
    println!("\nstreaming {decode_tokens} decode steps through one session...");
    let sid = engine.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    let t0 = Instant::now();
    for t in 0..decode_tokens {
        let s = seed.wrapping_mul(1000) + t as u64;
        let token = Tensor::randn(&[1, d_model], s);
        let resp = engine
            .decode_step(sid, token)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if resp.promoted {
            let layers: Vec<usize> = resp
                .layers
                .iter()
                .enumerate()
                .filter(|(_, l)| l.promoted)
                .map(|(i, _)| i)
                .collect();
            println!(
                "  prefix {} crossed N0 → promoted KV cache to recurrent state in layer(s) {layers:?}",
                resp.step
            );
        }
        // Periodic scrape snapshot: the serving counters a dashboard
        // would poll, straight from the Prometheus exposition.
        if (t + 1) % 256 == 0 {
            let scrape = engine.scrape();
            println!("  scrape @ step {}:", t + 1);
            for line in scrape.lines() {
                if line.starts_with("taylorshift_decode_steps_total")
                    || line.starts_with("taylorshift_decode_lane_depth_total")
                    || line.contains("decode_branch_step_time_us_count")
                {
                    println!("    {line}");
                }
            }
        }
    }
    let decode_wall = t0.elapsed().as_secs_f64();
    let stats = engine
        .close_stream(sid)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "decoded {} tokens in {decode_wall:.2}s ({:.0} tok/s), final branches {:?}, \
         state {} bytes, promoted at {:?}",
        stats.tokens,
        stats.tokens as f64 / decode_wall,
        stats.branches,
        stats.bytes,
        stats.promoted_at,
    );

    println!("\n{}", engine.metrics().summary());

    // Full exposition on request — point Prometheus' file discovery at
    // it, or diff scrapes across runs.
    if let Some(path) = args.get("scrape-out") {
        std::fs::write(path, engine.scrape())?;
        println!("wrote Prometheus exposition to {path}");
    }

    // --- flight recorder: what the engine keeps for the post-mortem ---
    // Induce the error path on a throwaway engine: a 1-session store
    // must evict the first stream when a second opens, so stepping the
    // first again fails with NeedsReprefill — and the engine snapshots
    // the ring events leading up to the error.
    println!("\ninducing a session eviction to demo the flight recorder...");
    let tiny = Engine::start_with(
        EngineConfig::builder()
            .max_sessions(1)
            .build()
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        || Ok(NullPrefill { sizes: vec![1, 8] }),
    )?;
    let victim = tiny.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    let token = Tensor::randn(&[1, d_model], seed);
    tiny.decode_step(victim, token.clone())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let _survivor = tiny.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    match tiny.decode_step(victim, token) {
        Ok(_) => println!("  (eviction did not trigger — budget too large?)"),
        Err(e) => {
            println!("  typed error as expected: {e}");
            if let Some(dump) = tiny.last_error_dump() {
                println!("  flight-recorder dump:\n{dump}");
            }
        }
    }

    // --- spill/restore: the same eviction with the disk tier enabled ---
    // A 1-session store with spill on parks the victim's full state
    // stack (KV rows or f64 Taylor moments) in a checksummed file;
    // touching the victim again restores it mid-stream instead of
    // failing with NeedsReprefill.
    println!("\nsame eviction with spill enabled: state parks on disk and restores...");
    let spill_dir =
        std::env::temp_dir().join(format!("taylorshift-demo-spill-{}", std::process::id()));
    let spilly = Engine::start_with(
        EngineConfig::builder()
            .max_sessions(1)
            .spill_enabled(true)
            .spill_dir(spill_dir.clone())
            .build()
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        || Ok(NullPrefill { sizes: vec![1, 8] }),
    )?;
    let victim = spilly.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    for t in 0..4u64 {
        let token = Tensor::randn(&[1, d_model], seed.wrapping_add(t));
        spilly
            .decode_step(victim, token)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let bump = spilly.submit_stream().map_err(|e| anyhow::anyhow!("{e}"))?;
    spilly
        .decode_step(bump, Tensor::randn(&[1, d_model], seed.wrapping_add(100)))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let resp = spilly
        .decode_step(victim, Tensor::randn(&[1, d_model], seed.wrapping_add(4)))
        .map_err(|e| anyhow::anyhow!("spilled stream did not restore: {e}"))?;
    let m = spilly.metrics();
    let restored = m.sessions_restored.load(std::sync::atomic::Ordering::Relaxed);
    if restored == 0 || resp.step != 5 {
        anyhow::bail!(
            "expected a transparent restore continuing at step 5, got step {} ({} restored)",
            resp.step,
            restored
        );
    }
    println!(
        "  victim restored mid-stream at step {}: spilled={} restored={} failures={} \
         restore p50 {:?}",
        resp.step,
        m.sessions_spilled.load(std::sync::atomic::Ordering::Relaxed),
        restored,
        m.spill_failures.load(std::sync::atomic::Ordering::Relaxed),
        m.restore_latency.quantile(0.5),
    );
    // Counters as JSON for CI, next to the BENCH_*.json artifacts.
    if let Some(path) = args.get("spill-out") {
        let j = taylorshift::util::json::Json::from_pairs(vec![
            (
                "spilled",
                taylorshift::util::json::Json::Num(
                    m.sessions_spilled.load(std::sync::atomic::Ordering::Relaxed) as f64,
                ),
            ),
            ("restored", taylorshift::util::json::Json::Num(restored as f64)),
            (
                "failures",
                taylorshift::util::json::Json::Num(
                    m.spill_failures.load(std::sync::atomic::Ordering::Relaxed) as f64,
                ),
            ),
            (
                "restored_bytes",
                taylorshift::util::json::Json::Num(
                    m.restored_state_bytes
                        .load(std::sync::atomic::Ordering::Relaxed) as f64,
                ),
            ),
            (
                "restore_p50_us",
                taylorshift::util::json::Json::Num(
                    m.restore_latency.quantile(0.5).as_micros() as f64,
                ),
            ),
        ]);
        std::fs::write(path, j.to_string())?;
        println!("  wrote spill/restore counters to {path}");
    }
    spilly
        .close_stream(victim)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    spilly.close_stream(bump).map_err(|e| anyhow::anyhow!("{e}"))?;
    drop(spilly);
    let _ = std::fs::remove_dir_all(spill_dir);

    println!(
        "\nadaptive crossover N0(16)≈{:.0}: buckets ≤256 → direct, ≥512 → efficient",
        taylorshift::attention::selector::Selector::analytical().crossover(16)
    );
    Ok(())
}

//! Serving demo: the coordinator under a mixed-length synthetic load.
//!
//! Shows the paper's "(and Back)" live: short requests route to the
//! direct-TaylorShift executables, long ones to efficient, per the
//! analytical crossover; the dynamic batcher fuses same-bucket
//! arrivals. Reports latency percentiles, throughput, batch occupancy
//! and the per-variant split.
//!
//! Run: `cargo run --release --example serve_longseq -- --requests 200`
//! Flags: --requests N --concurrency C --variant auto|direct|efficient
//!        --max-delay-ms D --seed S

use std::time::{Duration, Instant};
use taylorshift::coordinator::batcher::BatchPolicy;
use taylorshift::coordinator::engine::{Engine, EngineConfig, RegistryExecutor};
use taylorshift::data::listops::ListOpsGen;
use taylorshift::data::TaskGenerator;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 200);
    let concurrency = args.usize_or("concurrency", 16);
    let seed = args.u64_or("seed", 1);
    let buckets = vec![128usize, 256, 512, 1024];

    let mut cfg = EngineConfig {
        buckets: buckets.clone(),
        head_dim: 16,
        policy: BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_micros(
                (args.f64_or("max-delay-ms", 2.0) * 1000.0) as u64,
            ),
        },
        queue_limit: 512,
        forced_variant: None,
        selector: taylorshift::attention::selector::Selector::analytical(),
    };
    if let Some(v) = args.get("variant") {
        if v != "auto" {
            cfg.forced_variant = taylorshift::attention::AttentionVariant::parse(v);
        }
    }
    // Use a machine-measured crossover if crossover_sweep produced one.
    if let Some(cal) = args.get("calibration") {
        cfg.selector = taylorshift::attention::selector::Selector::from_json_file(
            std::path::Path::new(cal),
        )?;
    }

    let dir = args.str_or("artifacts-dir", "artifacts").to_string();
    println!("compiling serving executables (one per bucket × variant × batch)...");
    let t0 = Instant::now();
    let engine = Engine::start_with(cfg, move || {
        RegistryExecutor::new(&dir, "serve", &[128, 256, 512, 1024], &[1, 8])
    })?;
    println!("engine ready in {:.1}s", t0.elapsed().as_secs_f64());

    // Mixed-length load: bursts of short queries + a long-document tail,
    // issued from `concurrency` client threads.
    let gen_short = ListOpsGen { min_len: 20, max_len: 220, ..Default::default() };
    let gen_long = ListOpsGen { min_len: 300, max_len: 1000, max_args: 8, ..Default::default() };
    let mut rng = Pcg64::new(seed);
    let workloads: Vec<Vec<i32>> = (0..requests)
        .map(|_| {
            if rng.bernoulli(0.7) {
                gen_short.generate(&mut rng).tokens
            } else {
                gen_long.generate(&mut rng).tokens
            }
        })
        .collect();

    let engine = std::sync::Arc::new(engine);
    let t0 = Instant::now();
    let chunk = workloads.len().div_ceil(concurrency);
    std::thread::scope(|scope| {
        for part in workloads.chunks(chunk) {
            let engine = std::sync::Arc::clone(&engine);
            let part: Vec<Vec<i32>> = part.to_vec();
            scope.spawn(move || {
                for tokens in part {
                    match engine.infer(tokens) {
                        Ok(_) => {}
                        Err(e) => eprintln!("request failed: {e}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== load complete: {requests} requests in {wall:.2}s ({:.1} req/s) ===\n", requests as f64 / wall);
    println!("{}", engine.metrics().summary());
    println!(
        "\nadaptive crossover N0(16)≈{:.0}: buckets ≤256 → direct, ≥512 → efficient",
        taylorshift::attention::selector::Selector::analytical().crossover(16)
    );
    Ok(())
}

//! CI bench-regression gate for the streaming-decode flatness claim.
//!
//! Reads `recurrent_flat_ratio` (per-token recurrent decode time at the
//! longest prefix over the shortest — 1.0 means perfectly flat, i.e.
//! O(d³) per token independent of N) from the current bench output and
//! from a committed baseline, and fails if the current ratio regressed
//! by more than `--max-regress` (default 20%).
//!
//! Exit codes: 0 = pass, 1 = regression, 2 = missing/malformed input.
//!
//! ```text
//! cargo bench --bench decode_stream            # writes bench_out/decode_stream.json
//! cargo run --example bench_gate -- \
//!     --current bench_out/decode_stream.json \
//!     --baseline ../bench/baseline.json \
//!     --max-regress 0.2
//! ```

use taylorshift::util::cli::Args;
use taylorshift::util::json::Json;

fn read_ratio(path: &str) -> Result<f64, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let json = Json::parse(&text).map_err(|e| format!("{path}: parse error: {e}"))?;
    json.get("recurrent_flat_ratio")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing numeric key 'recurrent_flat_ratio'"))
}

fn main() {
    let args = Args::from_env();
    let current = args.str_or("current", "bench_out/decode_stream.json");
    let baseline = args.str_or("baseline", "../bench/baseline.json");
    let tol = args.f64_or("max-regress", 0.2);

    let (cur, base) = match (read_ratio(current), read_ratio(baseline)) {
        (Ok(c), Ok(b)) => (c, b),
        (c, b) => {
            for r in [c, b] {
                if let Err(e) = r {
                    eprintln!("bench_gate: {e}");
                }
            }
            std::process::exit(2);
        }
    };

    let limit = base * (1.0 + tol);
    println!(
        "bench_gate: recurrent_flat_ratio current={cur:.3} baseline={base:.3} \
         limit={limit:.3} (max-regress {:.0}%)",
        tol * 100.0
    );
    if cur > limit {
        println!("FAIL: flatness ratio regressed beyond tolerance");
        std::process::exit(1);
    }
    println!("PASS");
}

//! Table 8: linear vs 3-layer-CNN token embedding (App. D.5).
//!
//! Trains the efficient-TaylorShift model with both embeddings on each
//! task and reports the accuracy delta — the paper finds large gains on
//! the sequence tasks from the convolutional stem.
//!
//! Run: `cargo run --release --example ablation_embed -- --steps 150`

use taylorshift::bench_support::Table;
use taylorshift::data::task_by_name;
use taylorshift::runtime::{Registry, Runtime};
use taylorshift::train::TrainDriver;
use taylorshift::util::cli::Args;
use taylorshift::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 150);
    let seed = args.u64_or("seed", 42);
    let tasks: Vec<String> = args
        .get("tasks")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_else(|| vec!["listops".into(), "pixel".into(), "textbytes".into()]);

    let reg = Registry::open(Runtime::cpu()?, args.str_or("artifacts-dir", "artifacts"))?;
    let mut table = Table::new(&["Dataset", "lin. embed.", "conv. embed.", "Δ"]);

    for task in &tasks {
        let mut accs = Vec::new();
        for (label, artifact) in [
            ("lin", format!("{task}_efficient_train_b16")),
            ("conv", format!("{task}_efficient_conv_train_b16")),
        ] {
            print!("{task}/{label}: training {steps} steps ... ");
            let mut driver = TrainDriver::new(&reg, &artifact)?;
            let gen = task_by_name(task, driver.seq_len()).unwrap();
            let mut rng = Pcg64::new(seed);
            let report = driver.run(&gen, &mut rng, steps, |_| {})?;
            // Streaming accuracy over fresh batches (train-step acc on
            // unseen data) as the eval signal.
            let mut acc_sum = 0.0f32;
            let evals = 6;
            for _ in 0..evals {
                let b = taylorshift::data::batch::generate_batch(
                    &gen,
                    &mut rng,
                    driver.batch_size(),
                    driver.seq_len(),
                );
                acc_sum += driver.step_on(&b.tokens, &b.labels)?.acc;
            }
            let acc = (acc_sum / evals as f32) as f64 * 100.0;
            println!("acc {acc:.1}% ({:.2} steps/s)", report.steps_per_s);
            accs.push(acc);
        }
        table.row(&[
            task.clone(),
            format!("{:.1}", accs[0]),
            format!("{:.1}", accs[1]),
            format!("{:+.1}", accs[1] - accs[0]),
        ]);
    }
    println!("\n=== Table 8 (reduced scale): embedding ablation ===\n");
    table.print();
    Ok(())
}

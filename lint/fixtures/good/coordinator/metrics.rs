// Fixture: snake_case names with unit suffixes satisfy R5.

pub struct Registry {
    samples: Vec<(String, f64)>,
}

impl Registry {
    pub fn register_counter(&mut self, name: &str, value: f64) {
        self.samples.push((name.to_string(), value));
    }

    pub fn register_gauge(&mut self, name: &str, value: f64) {
        self.samples.push((name.to_string(), value));
    }

    pub fn register_histogram(&mut self, name: &str, value: f64) {
        self.samples.push((name.to_string(), value));
    }
}

pub fn export(reg: &mut Registry) {
    reg.register_counter("requests_served_total", 1.0);
    reg.register_gauge("session_state_bytes", 2.0);
    reg.register_histogram("queue_wait_us", 3.0);
}

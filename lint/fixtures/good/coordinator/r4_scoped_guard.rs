// Fixture: copying out under the lock and dropping the guard before
// the channel op is the sanctioned pattern; R4 must stay silent.

use std::sync::mpsc::Sender;
use std::sync::Mutex;

pub fn drain(lock: &Mutex<Vec<u64>>, tx: &Sender<u64>) {
    let guard = lock.lock().unwrap();
    let pending = guard.clone();
    drop(guard);
    for v in pending {
        tx.send(v).ok();
    }
}

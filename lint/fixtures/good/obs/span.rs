// Fixture: a lock-free, allocation-free span timer satisfies R6 —
// fixed-size thread-local buffer, atomics only.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static DROPPED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

pub struct SpanGuard {
    start_us: u64,
    armed: bool,
}

pub fn span(start_us: u64) -> SpanGuard {
    let armed = DEPTH.try_with(|d| d.get() < 8).unwrap_or(false);
    if !armed {
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
    SpanGuard { start_us, armed }
}

impl SpanGuard {
    pub fn is_armed(&self) -> bool {
        self.armed && self.start_us > 0
    }
}

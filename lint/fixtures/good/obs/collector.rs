// Fixture: atomics-only aggregation is R6-clean, and registered metric
// names under obs/ satisfy R5's naming scheme.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Collector {
    buckets: [AtomicU64; 4],
}

impl Collector {
    pub fn observe(&self, bucket: usize) {
        if let Some(b) = self.buckets.get(bucket) {
            b.fetch_add(1, Ordering::Relaxed);
        }
    }
}

pub fn export(reg: &mut crate::Registry, c: &Collector) {
    reg.register_counter("spans_recorded_total", c.buckets[0].load(Ordering::Relaxed) as f64);
    reg.register_gauge_f("span_time_us", 2.0);
}

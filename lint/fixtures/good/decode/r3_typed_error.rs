// Fixture: typed-error returns are the sanctioned alternative; R3
// must stay silent, including on non-panicking combinators.

pub fn step(state: Option<u64>) -> Result<u64, String> {
    state.ok_or_else(|| "missing decode state".to_string())
}

pub fn fallback(state: Option<u64>) -> u64 {
    state.unwrap_or(0)
}

// Fixture: a well-formed escape hatch (known slug + reason) silences
// R3 and raises no HATCH finding.

pub fn checked_step(state: Option<u64>) -> u64 {
    // lint: allow(panic) -- fixture: invariant is established by the caller one frame up
    state.unwrap()
}

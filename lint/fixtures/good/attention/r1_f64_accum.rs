// Fixture: f64 accumulation with one final rounding point is the
// sanctioned pattern; R1 must stay silent.

pub fn moment_sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for x in xs {
        acc += f64::from(*x);
    }
    acc as f32
}

// Fixture: both guard styles — `.max(EPS)` at the declaration and a
// `guard_*` helper — must satisfy R2.

pub fn normalize(row: &mut [f32], denom: f32) {
    let safe_denom = denom.max(1e-6);
    for x in row.iter_mut() {
        *x /= safe_denom;
    }
}

pub fn rescale(value: f64, y: &[f64]) -> f64 {
    let row_sum = guard_denom(y[0]);
    value / row_sum
}

fn guard_denom(x: f64) -> f64 {
    x.max(1e-12)
}

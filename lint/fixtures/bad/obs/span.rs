// Fixture: R6 must flag allocation on the span hot path — the span
// API runs inside every decode step.

pub struct SpanGuard {
    name: String,
}

pub fn span(name: &str) -> SpanGuard {
    SpanGuard {
        name: name.to_string(),
    }
}

pub fn drain(names: &[&str]) -> Vec<SpanGuard> {
    names.iter().map(|n| span(n)).collect()
}

// Fixture: R6 must flag blocking sync primitives anywhere in the
// observability layer.

use std::sync::Mutex;

pub struct Collector {
    counts: Mutex<[u64; 32]>,
}

impl Collector {
    pub fn observe(&self, bucket: usize) {
        if let Ok(mut c) = self.counts.lock() {
            c[bucket.min(31)] += 1;
        }
    }
}

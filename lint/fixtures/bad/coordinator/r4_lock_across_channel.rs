// Fixture: R4 must flag a Mutex guard held across a channel send and
// an RwLock read guard held across a compute call.

use std::sync::mpsc::Sender;
use std::sync::{Mutex, RwLock};

pub fn drain(lock: &Mutex<Vec<u64>>, tx: &Sender<u64>) {
    let guard = lock.lock().unwrap();
    for v in guard.iter() {
        tx.send(*v).ok();
    }
}

pub fn run_model(model: &RwLock<Model>, input: u64) -> u64 {
    let m = model.read().unwrap();
    m.forward(input)
}

pub struct Model;

impl Model {
    pub fn forward(&self, x: u64) -> u64 {
        x
    }
}

// Fixture: R5 must flag non-snake_case names and names missing a
// unit suffix, at registration call sites.

pub struct Registry {
    samples: Vec<(String, f64)>,
}

impl Registry {
    pub fn register_counter(&mut self, name: &str, value: f64) {
        self.samples.push((name.to_string(), value));
    }

    pub fn register_gauge(&mut self, name: &str, value: f64) {
        self.samples.push((name.to_string(), value));
    }
}

pub fn export(reg: &mut Registry) {
    reg.register_counter("RequestsServed", 1.0);
    reg.register_gauge("queue_depth", 2.0);
}

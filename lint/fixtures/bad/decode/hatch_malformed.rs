// Fixture: HATCH must flag a reason-less hatch and an unknown slug.
// The reason-less hatch still suppresses its R3 finding; the unknown
// slug suppresses nothing.

pub fn checked_step(state: Option<u64>) -> u64 {
    // lint: allow(panic)
    state.unwrap()
}

pub fn other_step(state: Option<u64>) -> u64 {
    // lint: allow(not-a-rule) -- unknown slug should be reported
    state.expect("present")
}

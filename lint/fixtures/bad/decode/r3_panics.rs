// Fixture: R3 must flag unwrap/expect/panic! on the hot path.

pub fn step(state: Option<u64>) -> u64 {
    state.unwrap()
}

pub fn checked(state: Option<u64>) -> u64 {
    state.expect("state present")
}

pub fn assert_ready(ready: bool) {
    if !ready {
        panic!("not ready");
    }
}

// Fixture: R1 must flag f32 and inferred-f32 accumulators.

pub fn moment_sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for x in xs {
        acc += *x;
    }
    acc
}

pub fn inferred_sum(xs: &[f32]) -> f32 {
    let mut weight_acc = 0.0;
    for x in xs {
        weight_acc += *x;
    }
    weight_acc
}

// Fixture: R2 must flag divisions by denominator-named values that
// carry no guard.

pub fn normalize(row: &mut [f32], denom: f32) {
    for x in row.iter_mut() {
        *x /= denom;
    }
}

pub fn rescale(value: f64, y: &[f64]) -> f64 {
    let row_sum = y[0];
    value / row_sum
}

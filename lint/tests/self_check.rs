//! Self-check: taylor-lint must run clean over the repo's own
//! sources. This is the same invocation CI gates on; if a change to
//! `rust/src` trips a rule, this test points at the exact line.

use std::path::Path;

#[test]
fn repo_sources_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let findings = taylor_lint::run_path(&root).expect("rust/src readable");
    assert!(
        findings.is_empty(),
        "taylor-lint must run clean on rust/src; fix the finding or add a \
         reasoned `// lint: allow(<slug>) -- <why>` hatch:\n{}",
        findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

//! Fixture tests: every rule must fire on its known-bad fixture and
//! stay silent on the known-good tree.

use std::collections::HashSet;
use std::path::Path;

use taylor_lint::Finding;

fn run_on(tree: &str) -> Vec<Finding> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(tree);
    taylor_lint::run_path(&root).expect("fixture tree readable")
}

fn render(findings: &[Finding]) -> String {
    findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn bad_fixtures_fire_every_rule() {
    let findings = run_on("bad");
    let rules: HashSet<&str> = findings.iter().map(|f| f.rule).collect();
    for rule in ["R1", "R2", "R3", "R4", "R5", "R6", "HATCH"] {
        assert!(
            rules.contains(rule),
            "rule {rule} produced no finding on fixtures/bad; got:\n{}",
            render(&findings)
        );
    }
}

#[test]
fn good_fixtures_are_clean() {
    let findings = run_on("good");
    assert!(
        findings.is_empty(),
        "fixtures/good must lint clean, got:\n{}",
        render(&findings)
    );
}

fn fired(findings: &[Finding], rule: &str, file: &str) -> bool {
    findings.iter().any(|f| f.rule == rule && f.file == file)
}

#[test]
fn each_rule_anchors_to_its_fixture_file() {
    let findings = run_on("bad");
    assert!(fired(&findings, "R1", "attention/r1_f32_accum.rs"));
    assert!(fired(&findings, "R2", "attention/r2_unguarded_div.rs"));
    assert!(fired(&findings, "R3", "decode/r3_panics.rs"));
    assert!(fired(&findings, "R4", "coordinator/r4_lock_across_channel.rs"));
    assert!(fired(&findings, "R5", "coordinator/metrics.rs"));
    assert!(fired(&findings, "R6", "obs/r6_locked_collector.rs"));
    assert!(fired(&findings, "R6", "obs/span.rs"));
    assert!(fired(&findings, "HATCH", "decode/hatch_malformed.rs"));
}

#[test]
fn r3_fires_once_per_panic_site() {
    let findings = run_on("bad");
    let r3: Vec<&Finding> = findings
        .iter()
        .filter(|f| f.rule == "R3" && f.file == "decode/r3_panics.rs")
        .collect();
    // unwrap(), expect(), panic! — one finding each.
    assert_eq!(r3.len(), 3, "got:\n{}", render(&findings));
}

#[test]
fn reasonless_hatch_suppresses_rule_but_reports_hatch() {
    let findings = run_on("bad");
    // The `allow(panic)` hatch without a reason still silences its R3
    // finding (line 7) but is reported itself.
    assert!(!findings
        .iter()
        .any(|f| f.rule == "R3" && f.file == "decode/hatch_malformed.rs" && f.line == 7));
    assert!(findings
        .iter()
        .any(|f| f.rule == "HATCH" && f.file == "decode/hatch_malformed.rs" && f.line == 6));
    // The unknown slug suppresses nothing: its R3 survives.
    assert!(findings
        .iter()
        .any(|f| f.rule == "R3" && f.file == "decode/hatch_malformed.rs" && f.line == 12));
}

//! A minimal Rust lexer: just enough token structure for the lint
//! rules — identifiers, numbers, strings, and punctuation with
//! two-character operators merged — plus line numbers and captured
//! comments (escape hatches live in comments).
//!
//! This is deliberately not a full Rust grammar. The rules only need
//! to distinguish "identifier next to `+=`" from "string containing
//! `+=`", so the lexer's one hard job is never misclassifying string,
//! char, comment, or raw-string boundaries.

/// Token class. `Punct` covers all operators and delimiters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One token with the line it starts on (1-based).
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

/// A comment (line or block, including delimiters) with its start line.
pub type Comment = (usize, String);

/// Two-character operators kept as single tokens so `+=` never splits
/// into `+` `=` (rule R1 keys on the compound token).
const MERGE2: [&str; 14] = [
    "+=", "-=", "*=", "/=", "==", "!=", "<=", ">=", "&&", "||", "::", "->", "=>", "..",
];

fn lossy(bytes: &[u8], start: usize, end: usize) -> String {
    String::from_utf8_lossy(&bytes[start..end.min(bytes.len())]).into_owned()
}

/// Length in bytes of a char literal at `start` (which must index a
/// `'`), or `None` if the quote starts a lifetime or stray apostrophe.
fn char_lit_len(src: &str, start: usize) -> Option<usize> {
    let rest = &src[start + 1..];
    let mut it = rest.char_indices();
    let (_, first) = it.next()?;
    if first == '\\' {
        // `'\x'`-style: the escaped char, then anything up to the
        // closing quote (covers `'\u{1F600}'`).
        it.next()?;
        for (off, ch) in it {
            if ch == '\'' {
                return Some(1 + off + 1);
            }
        }
        None
    } else if first != '\'' {
        let (off, ch) = it.next()?;
        if ch == '\'' {
            Some(1 + off + 1)
        } else {
            None
        }
    } else {
        None
    }
}

/// If `start` begins a raw string (`r"…"`, `r#"…"#`, `br"…"`), return
/// (index just past the opening quote, number of `#`s).
fn raw_string_open(bytes: &[u8], start: usize) -> Option<(usize, usize)> {
    let mut j = start;
    if bytes[j] == b'b' {
        j += 1;
    }
    if j >= bytes.len() || bytes[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < bytes.len() && bytes[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < bytes.len() && bytes[j] == b'"' {
        Some((j + 1, hashes))
    } else {
        None
    }
}

fn find_sub(bytes: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || from >= bytes.len() {
        return None;
    }
    bytes[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| from + p)
}

/// Tokenize `src`, returning tokens and comments separately.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = bytes[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // Line comment.
        if c == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
            let j = find_sub(bytes, i, b"\n").unwrap_or(n);
            comments.push((line, lossy(bytes, i, j)));
            i = j;
            continue;
        }
        // Block comment (nesting, as in Rust).
        if c == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if bytes[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if bytes[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if bytes[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push((start_line, lossy(bytes, i, j)));
            i = j;
            continue;
        }
        // Raw string (must precede the ident branch: `r`/`b` are alpha).
        if c == b'r' || c == b'b' {
            if let Some((body, hashes)) = raw_string_open(bytes, i) {
                let mut closer = vec![b'"'];
                closer.resize(1 + hashes, b'#');
                let k = find_sub(bytes, body, &closer).unwrap_or(n);
                let end = (k + closer.len()).min(n);
                let text = lossy(bytes, i, end);
                line += text.matches('\n').count();
                toks.push(Tok {
                    kind: Kind::Str,
                    text,
                    line,
                });
                i = end;
                continue;
            }
        }
        // Regular / byte string.
        if c == b'"' || (c == b'b' && i + 1 < n && bytes[i + 1] == b'"') {
            let start_line = line;
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n {
                if bytes[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if bytes[j] == b'\n' {
                    line += 1;
                }
                if bytes[j] == b'"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            let end = j.min(n);
            toks.push(Tok {
                kind: Kind::Str,
                text: lossy(bytes, i, end),
                line: start_line,
            });
            i = end;
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if let Some(len) = char_lit_len(src, i) {
                toks.push(Tok {
                    kind: Kind::Char,
                    text: lossy(bytes, i, i + len),
                    line,
                });
                i += len;
                continue;
            }
            if i + 1 < n && (bytes[i + 1].is_ascii_alphabetic() || bytes[i + 1] == b'_') {
                let mut j = i + 2;
                while j < n && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_') {
                    j += 1;
                }
                toks.push(Tok {
                    kind: Kind::Lifetime,
                    text: lossy(bytes, i, j),
                    line,
                });
                i = j;
                continue;
            }
            toks.push(Tok {
                kind: Kind::Punct,
                text: "'".to_string(),
                line,
            });
            i += 1;
            continue;
        }
        // Identifier / keyword.
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i + 1;
            while j < n && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_') {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: lossy(bytes, i, j),
                line,
            });
            i = j;
            continue;
        }
        // Number: hex, or decimal with optional fraction / exponent /
        // type suffix. The fraction requires a digit after `.` so that
        // `0..n` lexes as `0` `..` `n`.
        if c.is_ascii_digit() {
            let mut j = i + 1;
            if c == b'0' && j < n && bytes[j] == b'x' && j + 1 < n && is_hex(bytes[j + 1]) {
                j += 1;
                while j < n && is_hex(bytes[j]) {
                    j += 1;
                }
            } else {
                while j < n && (bytes[j].is_ascii_digit() || bytes[j] == b'_') {
                    j += 1;
                }
                if j + 1 < n && bytes[j] == b'.' && bytes[j + 1].is_ascii_digit() {
                    j += 2;
                    while j < n && (bytes[j].is_ascii_digit() || bytes[j] == b'_') {
                        j += 1;
                    }
                }
                if j < n && (bytes[j] == b'e' || bytes[j] == b'E') {
                    let mut k = j + 1;
                    if k < n && (bytes[k] == b'+' || bytes[k] == b'-') {
                        k += 1;
                    }
                    if k < n && bytes[k].is_ascii_digit() {
                        while k < n && bytes[k].is_ascii_digit() {
                            k += 1;
                        }
                        j = k;
                    }
                }
                while j < n && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_') {
                    j += 1;
                }
            }
            toks.push(Tok {
                kind: Kind::Num,
                text: lossy(bytes, i, j),
                line,
            });
            i = j;
            continue;
        }
        // Two-char operator. Compared as bytes: `i + 2` may not be a
        // char boundary when a multi-byte char follows the operator.
        if i + 1 < n {
            let two = [bytes[i], bytes[i + 1]];
            if MERGE2.iter().any(|m| m.as_bytes() == two.as_slice()) {
                toks.push(Tok {
                    kind: Kind::Punct,
                    text: lossy(bytes, i, i + 2),
                    line,
                });
                i += 2;
                continue;
            }
        }
        toks.push(Tok {
            kind: Kind::Punct,
            text: lossy(bytes, i, i + 1),
            line,
        });
        i += 1;
    }
    (toks, comments)
}

fn is_hex(c: u8) -> bool {
    c.is_ascii_hexdigit() || c == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).0.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn merges_compound_operators() {
        assert_eq!(texts("a += b"), ["a", "+=", "b"]);
        assert_eq!(texts("x /= y;"), ["x", "/=", "y", ";"]);
        assert_eq!(texts("for i in 0..n"), ["for", "i", "in", "0", "..", "n"]);
    }

    #[test]
    fn float_and_exponent_literals_stay_whole() {
        assert_eq!(texts("den.max(1e-12)"), ["den", ".", "max", "(", "1e-12", ")"]);
        assert_eq!(texts("let s = 0.0f64;"), ["let", "s", "=", "0.0f64", ";"]);
    }

    #[test]
    fn strings_and_comments_do_not_leak_tokens() {
        let (toks, comments) = lex("let s = \"a += b\"; // x += y\n");
        assert_eq!(toks.iter().filter(|t| t.text == "+=").count(), 0);
        assert_eq!(comments.len(), 1);
        assert!(comments[0].1.contains("x += y"));
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let (toks, _) = lex("let r = r#\"den / sum\"#; let c = '/'; fn f<'a>() {}");
        assert_eq!(toks.iter().filter(|t| t.text == "/").count(), 0);
        assert!(toks.iter().any(|t| t.kind == Kind::Lifetime && t.text == "'a"));
        assert!(toks.iter().any(|t| t.kind == Kind::Char && t.text == "'/'"));
    }

    #[test]
    fn line_numbers_are_one_based_and_track_newlines() {
        let (toks, comments) = lex("a\nb\n// c\nd\n");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(comments[0].0, 3);
        assert_eq!(toks[2].line, 4);
    }

    #[test]
    fn block_comments_nest_and_count_lines() {
        let (toks, comments) = lex("/* a /* b */ c\nmore */ after\n");
        assert_eq!(comments.len(), 1);
        assert_eq!(toks.len(), 1);
        assert_eq!(toks[0].text, "after");
        assert_eq!(toks[0].line, 2);
    }
}

//! taylor-lint: repo-specific static analysis for the TaylorShift
//! serving stack.
//!
//! The general-purpose toolchain (rustc + clippy) cannot see this
//! repo's *paper* invariants: that Taylor-moment accumulation must run
//! in f64, that normalizer divisions must be guarded, that the serving
//! hot path must not panic, that lock guards must not be held across
//! channel handoffs, and that exported metrics follow one naming
//! convention. This crate checks exactly those, over a lexed (not
//! parsed) token stream — see `lint/README.md` for the rule catalogue
//! and escape-hatch policy.
//!
//! Usage: `cargo run -p taylor-lint -- rust/src [--json]`.

mod lexer;
mod rules;

pub use rules::{lint_source, slug_for, Finding};

use std::path::{Path, PathBuf};

/// Lint a file or directory tree. Directories are walked recursively;
/// `target/`, `vendor/`, and dot-directories are skipped, and only
/// `.rs` files are linted. Paths in findings are relative to `root`.
pub fn run_path(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    if root.is_file() {
        let src = std::fs::read_to_string(root)?;
        let rel = root
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        findings.extend(lint_source(&rel, &src));
        return Ok(findings);
    }
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    for full in files {
        let rel = full
            .strip_prefix(root)
            .unwrap_or(&full)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&full)?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "target" || name == "vendor" || name.starts_with('.') {
                continue;
            }
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Machine-readable report: `{"count": N, "findings": [...]}`.
pub fn to_json(findings: &[Finding]) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"count\": {},\n", findings.len()));
    s.push_str("  \"findings\": [\n");
    for (i, f) in findings.iter().enumerate() {
        let sep = if i + 1 == findings.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}{}\n",
            f.rule,
            escape_json(&f.file),
            f.line,
            escape_json(&f.message),
            sep
        ));
    }
    s.push_str("  ]\n}");
    s
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_shape_and_escaping() {
        let findings = vec![Finding {
            rule: "R2",
            file: "attention/a.rs".to_string(),
            line: 7,
            message: "division by `den` \"raw\"".to_string(),
        }];
        let s = to_json(&findings);
        assert!(s.contains("\"count\": 1"));
        assert!(s.contains("\"rule\": \"R2\""));
        assert!(s.contains("\"line\": 7"));
        assert!(s.contains("\\\"raw\\\""));
        let empty = to_json(&[]);
        assert!(empty.contains("\"count\": 0"));
    }
}

//! CLI for taylor-lint.
//!
//! Exit codes: 0 = clean, 1 = findings reported, 2 = usage or I/O
//! error (so CI can tell "rule violation" from "could not run").

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: taylor-lint [--json] <path>...\n\n\
    Lints .rs files under each <path> (file or directory) against the\n\
    TaylorShift repo invariants R1-R5. See lint/README.md.";

fn main() -> ExitCode {
    let mut as_json = false;
    let mut roots: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => as_json = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            _ => roots.push(PathBuf::from(arg)),
        }
    }
    if roots.is_empty() {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }
    let mut findings = Vec::new();
    for root in &roots {
        match taylor_lint::run_path(root) {
            Ok(found) => findings.extend(found),
            Err(e) => {
                eprintln!("taylor-lint: {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }
    if as_json {
        println!("{}", taylor_lint::to_json(&findings));
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
        println!("{} finding(s)", findings.len());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

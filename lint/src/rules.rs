//! The six taylor-lint rules, the escape-hatch grammar, and
//! suppression.
//!
//! Rules are scoped by relative path (so fixtures exercise them by
//! living under matching directory names):
//!
//! - **R1 f32-accum** (`attention/`, `decode/`, `model/`): compound
//!   accumulation (`+=`) must target an `f64` accumulator.
//! - **R2 unguarded-div** (same scope): division by a moment/sum-named
//!   denominator must be guarded (`guard_denom`, `.max(EPS)`).
//! - **R3 panic** (`coordinator/engine.rs`, `decode/`, `model/` —
//!   including the spill/restore tier in `model/spill.rs` and
//!   `model/store.rs`): no `unwrap`/`expect`/`panic!` on the serving
//!   hot path.
//! - **R4 lock-across-channel** (`coordinator/`, `util/threadpool.rs`):
//!   a Mutex/RwLock guard must not stay live across channel ops or
//!   compute calls.
//! - **R5 metric-name** (`coordinator/metrics.rs`, `obs/`): registered
//!   metric names must be snake_case with a `_bytes`/`_us`/`_total`
//!   suffix.
//! - **R6 obs-hot-path** (`obs/`): no blocking sync primitives
//!   (Mutex/RwLock/Condvar) anywhere in the observability layer, and no
//!   allocation (`Vec`/`String`/`Box`, `vec!`/`format!`, `.to_string()`
//!   etc.) in `obs/span.rs` — the span API sits on the decode hot path.
//!
//! Escape hatch: `// lint: allow(<slug>) -- <reason>` on the finding's
//! line or the line above. A hatch with a missing/short reason or an
//! unknown slug is itself a finding (rule `HATCH`).

use crate::lexer::{lex, Comment, Kind, Tok};
use std::collections::{HashMap, HashSet};

/// One lint finding. `rule` is the rule ID (`R1`..`R6`, `HATCH`).
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// Escape-hatch slug for a suppressible rule.
pub fn slug_for(rule: &str) -> Option<&'static str> {
    match rule {
        "R1" => Some("f32-accum"),
        "R2" => Some("unguarded-div"),
        "R3" => Some("panic"),
        "R4" => Some("lock-across-channel"),
        "R5" => Some("metric-name"),
        "R6" => Some("obs-hot-path"),
        _ => None,
    }
}

const KNOWN_SLUGS: [&str; 6] = [
    "f32-accum",
    "unguarded-div",
    "panic",
    "lock-across-channel",
    "metric-name",
    "obs-hot-path",
];

const DENOM_NAMES: [&str; 6] = ["den", "denom", "sum", "total", "norm", "z"];
const DENOM_SUFFIXES: [&str; 5] = ["_den", "_denom", "_sum", "_total", "_norm"];

const CHANNEL_OPS: [&str; 5] = ["send", "recv", "try_recv", "recv_timeout", "send_timeout"];
const COMPUTE_CALLS: [&str; 3] = ["step", "forward", "forward_batch"];

// ------------------------------------------------------------- scoping

fn in_dir(rel: &str, dir: &str) -> bool {
    rel.starts_with(&format!("{dir}/")) || rel.contains(&format!("/{dir}/"))
}

fn is_file(rel: &str, file: &str) -> bool {
    rel == file || rel.ends_with(&format!("/{file}"))
}

fn r1r2_scope(rel: &str) -> bool {
    in_dir(rel, "attention") || in_dir(rel, "decode") || in_dir(rel, "model")
}

fn r3_scope(rel: &str) -> bool {
    is_file(rel, "coordinator/engine.rs") || in_dir(rel, "decode") || in_dir(rel, "model")
}

fn r4_scope(rel: &str) -> bool {
    in_dir(rel, "coordinator") || is_file(rel, "util/threadpool.rs")
}

fn r5_scope(rel: &str) -> bool {
    is_file(rel, "coordinator/metrics.rs") || in_dir(rel, "obs")
}

fn r6_scope(rel: &str) -> bool {
    in_dir(rel, "obs")
}

// ------------------------------------------------------- token helpers

/// Index of the token closing the bracket at `open_idx`.
fn match_close(toks: &[Tok], open_idx: usize) -> usize {
    let open = toks[open_idx].text.clone();
    let close = match open.as_str() {
        "{" => "}",
        "(" => ")",
        _ => "]",
    };
    let mut depth = 0i64;
    for (i, t) in toks.iter().enumerate().skip(open_idx) {
        if t.text == open {
            depth += 1;
        } else if t.text == close {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Line ranges covered by `#[cfg(test)]` / `#[test]` items: lint rules
/// do not apply inside tests (tests may unwrap freely).
fn test_lines(toks: &[Tok]) -> HashSet<usize> {
    let mut out = HashSet::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && i + 1 < toks.len() && toks[i + 1].text == "[" {
            let close = match_close(toks, i + 1);
            let attr: Vec<&str> = toks
                .get(i + 2..close)
                .unwrap_or(&[])
                .iter()
                .map(|t| t.text.as_str())
                .collect();
            let is_test = attr.first() == Some(&"test")
                || (attr.len() >= 3 && attr[0] == "cfg" && attr[1] == "(" && attr[2] == "test");
            if is_test {
                let mut j = close + 1;
                while j < toks.len() && toks[j].text != "{" {
                    if toks[j].text == ";" {
                        break;
                    }
                    j += 1;
                }
                if j < toks.len() && toks[j].text == "{" {
                    let end = match_close(toks, j);
                    for ln in toks[i].line..=toks[end].line {
                        out.insert(ln);
                    }
                    i = end + 1;
                    continue;
                }
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    out
}

/// Index of the `;` ending the statement starting at `i` (brackets
/// opened inside the statement are skipped over).
fn stmt_end(toks: &[Tok], mut i: usize) -> usize {
    let mut depth = 0i64;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => depth -= 1,
            ";" if depth <= 0 => return i,
            _ => {}
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Nearest preceding `let [mut] <name> … ;` statement, as an inclusive
/// token range.
fn find_decl(toks: &[Tok], use_idx: usize, name: &str) -> Option<(usize, usize)> {
    let mut i = use_idx;
    while i > 0 {
        i -= 1;
        if toks[i].kind == Kind::Ident && toks[i].text == "let" {
            let mut j = i + 1;
            if j < toks.len() && toks[j].text == "mut" {
                j += 1;
            }
            if j < toks.len() && toks[j].kind == Kind::Ident && toks[j].text == name {
                return Some((i, stmt_end(toks, i)));
            }
        }
    }
    None
}

/// Absorb the postfix chain (field/method/index accesses) starting at
/// the primary token `j`, returning all token texts in the chain.
fn chain_after(toks: &[Tok], j: usize) -> Vec<String> {
    let mut texts = vec![toks[j].text.clone()];
    let mut k = j + 1;
    while k < toks.len() {
        let t = toks[k].text.as_str();
        if t == "." || t == "::" {
            texts.push(t.to_string());
            k += 1;
            if k < toks.len() {
                texts.push(toks[k].text.clone());
                k += 1;
            }
            continue;
        }
        if t == "(" || t == "[" {
            let close = match_close(toks, k);
            texts.extend(toks[k..=close].iter().map(|x| x.text.clone()));
            k = close + 1;
            continue;
        }
        break;
    }
    texts
}

/// `true` if the texts contain a `.max(` call anywhere.
fn has_max_call<S: AsRef<str>>(texts: &[S]) -> bool {
    texts.windows(3).any(|w| {
        w[0].as_ref() == "." && w[1].as_ref() == "max" && w[2].as_ref() == "("
    })
}

fn denom_name_matches(name: &str) -> bool {
    DENOM_NAMES.contains(&name) || DENOM_SUFFIXES.iter().any(|s| name.ends_with(s))
}

/// Float-literal shape that infers its type from use: `1.5`, `1.`-free
/// forms like `0.0`, `1e-3` — but not suffixed forms (`0.0f32`).
fn is_float_literal(s: &str) -> bool {
    let b = s.as_bytes();
    if b.first().is_none_or(|c| !c.is_ascii_digit()) {
        return false;
    }
    let mut i = 1usize;
    while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
        i += 1;
    }
    if i < b.len() && b[i] == b'.' {
        i += 1;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == b.len() {
            return true;
        }
    }
    exponent_to_end(b, i)
}

fn exponent_to_end(b: &[u8], mut i: usize) -> bool {
    if i >= b.len() || (b[i] != b'e' && b[i] != b'E') {
        return false;
    }
    i += 1;
    if i < b.len() && (b[i] == b'+' || b[i] == b'-') {
        i += 1;
    }
    if i >= b.len() || !b[i].is_ascii_digit() {
        return false;
    }
    while i < b.len() && b[i].is_ascii_digit() {
        i += 1;
    }
    i == b.len()
}

enum FloatKind {
    F32,
    F64,
    Inferred,
}

/// Accumulator type evidence from its `let` declaration tokens.
fn decl_float_kind(decl: &[Tok]) -> Option<FloatKind> {
    if decl.iter().any(|t| t.text.contains("f64")) {
        return Some(FloatKind::F64);
    }
    if decl.iter().any(|t| t.text.contains("f32")) {
        return Some(FloatKind::F32);
    }
    if decl
        .iter()
        .any(|t| t.kind == Kind::Num && is_float_literal(&t.text))
    {
        return Some(FloatKind::Inferred);
    }
    None
}

// --------------------------------------------------------------- rules

/// R1: `+=` accumulation onto an f32 (or inferred-f32) accumulator.
fn rule_r1(rel: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !r1r2_scope(rel) {
        return;
    }
    for i in 1..toks.len() {
        if toks[i].text != "+=" {
            continue;
        }
        let lhs = &toks[i - 1];
        if lhs.kind != Kind::Ident {
            continue;
        }
        // `x.field += …` / `*slot += …` accumulate through a place we
        // cannot type-resolve here; skip.
        if i >= 2 && (toks[i - 2].text == "." || toks[i - 2].text == "*") {
            continue;
        }
        let Some((ds, de)) = find_decl(toks, i, &lhs.text) else {
            continue;
        };
        match decl_float_kind(&toks[ds..=de]) {
            Some(FloatKind::F32) => findings.push(Finding {
                rule: "R1",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "accumulator `{}` is f32; Taylor-moment accumulation must run in f64 \
                     before the single f32 rounding point",
                    lhs.text
                ),
            }),
            Some(FloatKind::Inferred) => {
                let end = stmt_end(toks, i);
                let rhs = &toks[i + 1..=end];
                if !rhs.iter().any(|t| t.text.contains("f64")) {
                    findings.push(Finding {
                        rule: "R1",
                        file: rel.to_string(),
                        line: toks[i].line,
                        message: format!(
                            "accumulator `{}` infers f32 from its uses; declare it f64 \
                             (e.g. `0.0f64`) for Taylor-moment accumulation",
                            lhs.text
                        ),
                    });
                }
            }
            _ => {}
        }
    }
}

/// R2: division by a denominator-named value with no guard in its use
/// chain or declaration.
fn rule_r2(rel: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !r1r2_scope(rel) {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].text != "/" && toks[i].text != "/=" {
            continue;
        }
        let j = i + 1;
        if j >= toks.len() || toks[j].kind != Kind::Ident {
            continue;
        }
        let root = toks[j].text.clone();
        if root.contains("guard") || has_max_call(&chain_after(toks, j)) {
            continue;
        }
        if !denom_name_matches(&root) {
            continue;
        }
        if let Some((ds, de)) = find_decl(toks, i, &root) {
            let decl = &toks[ds..=de];
            if decl.iter().any(|x| x.text.contains("guard")) {
                continue;
            }
            let dtexts: Vec<&str> = decl.iter().map(|x| x.text.as_str()).collect();
            if has_max_call(&dtexts) {
                continue;
            }
        }
        findings.push(Finding {
            rule: "R2",
            file: rel.to_string(),
            line: toks[i].line,
            message: format!(
                "division by `{root}` (a Taylor-softmax normalizer) without a guard; \
                 wrap it in `guard_denom`/`.max(EPS)` or branch explicitly"
            ),
        });
    }
}

/// R3: `unwrap`/`expect`/`panic!` on the serving hot path.
fn rule_r3(rel: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !r3_scope(rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        let nxt = toks.get(i + 1).map_or("", |x| x.text.as_str());
        if (t.text == "unwrap" || t.text == "expect")
            && i > 0
            && toks[i - 1].text == "."
            && nxt == "("
        {
            findings.push(Finding {
                rule: "R3",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "`.{}()` on the serving hot path; return a typed error instead",
                    t.text
                ),
            });
        } else if t.text == "panic" && nxt == "!" {
            findings.push(Finding {
                rule: "R3",
                file: rel.to_string(),
                line: t.line,
                message: "`panic!` on the serving hot path; return a typed error instead"
                    .to_string(),
            });
        }
    }
}

/// R4: a lock guard staying live across channel ops or compute calls.
/// The live region runs from the guard's `let` to the close of the
/// enclosing block, or to an explicit `drop(guard)`.
fn rule_r4(rel: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !r4_scope(rel) {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].kind != Kind::Ident || toks[i].text != "let" {
            continue;
        }
        let end = stmt_end(toks, i);
        let stmt_texts: Vec<&str> = toks[i..=end].iter().map(|t| t.text.as_str()).collect();
        let has_lock = stmt_texts.windows(3).any(|w| {
            w[0] == "." && (w[1] == "lock" || w[1] == "read" || w[1] == "write") && w[2] == "("
        });
        if !has_lock {
            continue;
        }
        let mut j = i + 1;
        if j < toks.len() && toks[j].text == "mut" {
            j += 1;
        }
        if j >= toks.len() || toks[j].kind != Kind::Ident {
            continue;
        }
        let guard_name = toks[j].text.clone();
        let mut depth = 0i64;
        let mut k = end + 1;
        while k < toks.len() {
            let txt = toks[k].text.as_str();
            if txt == "{" {
                depth += 1;
            } else if txt == "}" {
                depth -= 1;
                if depth < 0 {
                    break;
                }
            } else if txt == "drop"
                && k + 2 < toks.len()
                && toks[k + 1].text == "("
                && toks[k + 2].text == guard_name
            {
                break;
            } else if toks[k].kind == Kind::Ident
                && k > 0
                && toks[k - 1].text == "."
                && toks.get(k + 1).is_some_and(|t| t.text == "(")
            {
                if CHANNEL_OPS.contains(&txt) {
                    findings.push(Finding {
                        rule: "R4",
                        file: rel.to_string(),
                        line: toks[k].line,
                        message: format!(
                            "`{guard_name}` (a Mutex/RwLock guard) is held across channel \
                             `{txt}`; drop the guard first"
                        ),
                    });
                } else if COMPUTE_CALLS.contains(&txt) || txt.starts_with("taylor_") {
                    findings.push(Finding {
                        rule: "R4",
                        file: rel.to_string(),
                        line: toks[k].line,
                        message: format!(
                            "`{guard_name}` (a Mutex/RwLock guard) is held across compute \
                             call `{txt}`; drop the guard first"
                        ),
                    });
                }
            }
            k += 1;
        }
    }
}

fn metric_name_ok(name: &str) -> bool {
    let b = name.as_bytes();
    let snake = !b.is_empty()
        && b[0].is_ascii_lowercase()
        && b.iter()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == b'_');
    snake && ["_bytes", "_us", "_total"].iter().any(|s| name.ends_with(s))
}

/// R5: metric names passed to `register_counter`/`register_gauge`/
/// `register_gauge_f`/`register_histogram` must be snake_case with a
/// unit suffix.
fn rule_r5(rel: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !r5_scope(rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        if t.text != "register_counter"
            && t.text != "register_gauge"
            && t.text != "register_gauge_f"
            && t.text != "register_histogram"
        {
            continue;
        }
        // Skip the definitions themselves.
        if i > 0 && toks[i - 1].text == "fn" {
            continue;
        }
        if toks.get(i + 1).is_none_or(|x| x.text != "(") {
            continue;
        }
        let close = match_close(toks, i + 1);
        let Some(inner) = toks.get(i + 2..close) else {
            continue;
        };
        let Some(lit) = inner.iter().find(|x| x.kind == Kind::Str) else {
            continue;
        };
        let name = lit.text.trim_matches('"').to_string();
        if !metric_name_ok(&name) {
            findings.push(Finding {
                rule: "R5",
                file: rel.to_string(),
                line: lit.line,
                message: format!(
                    "metric name `{name}` must be snake_case with a unit suffix \
                     (_bytes, _us, _total)"
                ),
            });
        }
    }
}

const R6_LOCK_TYPES: [&str; 3] = ["Mutex", "RwLock", "Condvar"];
const R6_ALLOC_TYPES: [&str; 3] = ["Vec", "String", "Box"];
const R6_ALLOC_MACROS: [&str; 2] = ["vec", "format"];
const R6_ALLOC_METHODS: [&str; 3] = ["to_string", "to_owned", "collect"];

/// R6: the observability layer must stay lock-free — no blocking sync
/// primitives anywhere under `obs/` — and the span API (`obs/span.rs`)
/// must additionally be allocation-free, because every decode step
/// opens spans on the hot path.
fn rule_r6(rel: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !r6_scope(rel) {
        return;
    }
    let span_file = is_file(rel, "obs/span.rs");
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        let txt = t.text.as_str();
        let nxt = toks.get(i + 1).map_or("", |x| x.text.as_str());
        if R6_LOCK_TYPES.contains(&txt) {
            findings.push(Finding {
                rule: "R6",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "`{txt}` in the observability layer; obs/ must stay lock-free \
                     (atomics and thread-locals only)"
                ),
            });
            continue;
        }
        if !span_file {
            continue;
        }
        if R6_ALLOC_TYPES.contains(&txt) {
            findings.push(Finding {
                rule: "R6",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "`{txt}` in obs/span.rs; the span hot path must not allocate \
                     (use fixed-size buffers)"
                ),
            });
        } else if R6_ALLOC_MACROS.contains(&txt) && nxt == "!" {
            findings.push(Finding {
                rule: "R6",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "`{txt}!` in obs/span.rs; the span hot path must not allocate"
                ),
            });
        } else if R6_ALLOC_METHODS.contains(&txt)
            && i > 0
            && toks[i - 1].text == "."
            && nxt == "("
        {
            findings.push(Finding {
                rule: "R6",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "`.{txt}()` in obs/span.rs; the span hot path must not allocate"
                ),
            });
        }
    }
}

// ------------------------------------------------------- escape hatch

/// Parse every `lint: allow(<slug>) -- <reason>` occurrence in one
/// comment's text.
fn parse_hatches(text: &str) -> Vec<(String, Option<String>)> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("lint:") {
        rest = &rest[pos + 5..];
        let after = rest.trim_start();
        let Some(args) = after.strip_prefix("allow(") else {
            continue;
        };
        let Some(close) = args.find(')') else {
            continue;
        };
        let slug = &args[..close];
        let slug_ok = !slug.is_empty()
            && slug
                .bytes()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-');
        if !slug_ok {
            continue;
        }
        let tail = args[close + 1..].trim_start();
        let reason = tail.strip_prefix("--").map(|r| {
            let line = &r[..r.find('\n').unwrap_or(r.len())];
            line.trim().to_string()
        });
        let reason = match reason {
            Some(r) if !r.is_empty() => Some(r),
            _ => None,
        };
        out.push((slug.to_string(), reason));
        rest = &args[close + 1..];
    }
    out
}

/// HATCH: malformed escape hatches are findings in their own right.
fn rule_hatch(rel: &str, comments: &[&Comment], findings: &mut Vec<Finding>) {
    for (line, text) in comments.iter().map(|c| (c.0, c.1.as_str())) {
        for (slug, reason) in parse_hatches(text) {
            if !KNOWN_SLUGS.contains(&slug.as_str()) {
                findings.push(Finding {
                    rule: "HATCH",
                    file: rel.to_string(),
                    line,
                    message: format!("unknown lint escape-hatch slug `{slug}`"),
                });
            } else if reason.as_deref().is_none_or(|r| r.len() < 8) {
                findings.push(Finding {
                    rule: "HATCH",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "escape hatch `allow({slug})` must carry a reason: \
                         `// lint: allow({slug}) -- <why>`"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------- per file

/// Lint one file's source. `rel` is the path relative to the lint
/// root, with `/` separators — rule scoping keys on it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let (toks, comments) = lex(src);
    let skip = test_lines(&toks);
    let mut pre: Vec<Finding> = Vec::new();
    rule_r1(rel, &toks, &mut pre);
    rule_r2(rel, &toks, &mut pre);
    rule_r3(rel, &toks, &mut pre);
    rule_r4(rel, &toks, &mut pre);
    rule_r5(rel, &toks, &mut pre);
    rule_r6(rel, &toks, &mut pre);
    pre.retain(|f| !skip.contains(&f.line));
    let non_test: Vec<&Comment> = comments.iter().filter(|c| !skip.contains(&c.0)).collect();
    rule_hatch(rel, &non_test, &mut pre);

    // Suppression: an `allow(<slug>)` comment on the finding's line or
    // the line above silences R1–R6 (never HATCH).
    let mut by_line: HashMap<usize, &str> = HashMap::new();
    for (ln, txt) in &comments {
        by_line.insert(*ln, txt.as_str());
    }
    pre.retain(|f| {
        let Some(slug) = slug_for(f.rule) else {
            return true;
        };
        let needle = format!("allow({slug})");
        let hit = [f.line, f.line.wrapping_sub(1)]
            .iter()
            .any(|ln| by_line.get(ln).is_some_and(|t| t.contains(&needle)));
        !hit
    });
    pre
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn r1_fires_on_f32_and_inferred_accumulators_only_in_scope() {
        let src = "fn f(xs: &[f32]) -> f32 {\n    let mut acc = 0.0f32;\n    for x in xs {\n        acc += *x;\n    }\n    acc\n}\n";
        assert_eq!(rules_of(&lint_source("attention/a.rs", src)), ["R1"]);
        assert!(lint_source("util/a.rs", src).is_empty());
        let ok = src.replace("0.0f32", "0.0f64");
        assert!(lint_source("attention/a.rs", &ok).is_empty());
    }

    #[test]
    fn r2_fires_on_unguarded_denominators() {
        let src = "fn f(y: &[f64]) -> f64 {\n    let denom = y[0];\n    1.0 / denom\n}\n";
        assert_eq!(rules_of(&lint_source("decode/a.rs", src)), ["R2"]);
        let ok = "fn f(y: &[f64]) -> f64 {\n    let denom = y[0].max(1e-12);\n    1.0 / denom\n}\n";
        assert!(lint_source("decode/a.rs", ok).is_empty());
        let ok2 = "fn f(y: &[f64]) -> f64 {\n    let denom = guard_denom(y[0]);\n    1.0 / denom\n}\n";
        assert!(lint_source("decode/a.rs", ok2).is_empty());
    }

    #[test]
    fn r3_fires_on_engine_but_not_other_coordinator_files() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        assert_eq!(rules_of(&lint_source("coordinator/engine.rs", src)), ["R3"]);
        assert!(lint_source("coordinator/queue.rs", src).is_empty());
    }

    #[test]
    fn r3_covers_the_session_spill_module() {
        // The spill/restore tier handles untrusted on-disk bytes, so
        // panics there are load-bearing: restore failures must stay
        // typed errors. Pin the scope so a future path shuffle cannot
        // silently drop it.
        let src = "fn restore(p: &Path) -> State {\n    read_spill(p).unwrap()\n}\n";
        assert_eq!(rules_of(&lint_source("model/spill.rs", src)), ["R3"]);
        assert_eq!(rules_of(&lint_source("rust/src/model/spill.rs", src)), ["R3"]);
        assert_eq!(rules_of(&lint_source("model/store.rs", src)), ["R3"]);
    }

    #[test]
    fn r4_lock_across_send_fires_and_drop_ends_the_region() {
        let bad = "fn f() {\n    let g = m.lock().unwrap();\n    tx.send(1).ok();\n}\n";
        assert_eq!(rules_of(&lint_source("coordinator/a.rs", bad)), ["R4"]);
        let ok = "fn f() {\n    let g = m.lock().unwrap();\n    let v = g.len();\n    drop(g);\n    tx.send(v).ok();\n}\n";
        assert!(lint_source("coordinator/a.rs", ok).is_empty());
    }

    #[test]
    fn r5_checks_names_at_call_sites_only() {
        let src = "fn export(r: &mut R) {\n    r.register_counter(\"BadName\", 1.0);\n    r.register_counter(\"good_total\", 1.0);\n}\nfn register_counter() {}\n";
        let found = lint_source("coordinator/metrics.rs", src);
        assert_eq!(rules_of(&found), ["R5"]);
        assert!(found[0].message.contains("BadName"));
    }

    #[test]
    fn r5_also_covers_obs_and_register_gauge_f() {
        let src = "fn render(e: &mut E) {\n    e.register_gauge_f(\"BadName\", 1.0);\n}\n";
        assert_eq!(rules_of(&lint_source("obs/prometheus.rs", src)), ["R5"]);
        let ok = "fn render(e: &mut E) {\n    e.register_gauge_f(\"good_total\", 1.0);\n}\n";
        assert!(lint_source("obs/prometheus.rs", ok).is_empty());
    }

    #[test]
    fn r6_flags_locks_anywhere_in_obs() {
        let src = "use std::sync::Mutex;\nfn f() {\n    let m = Mutex::new(0);\n    let _ = m;\n}\n";
        let found = lint_source("obs/collector.rs", src);
        assert_eq!(rules_of(&found), ["R6", "R6"]);
        assert!(lint_source("util/a.rs", src).is_empty(), "out of scope");
    }

    #[test]
    fn r6_flags_allocation_only_in_span_file() {
        let src = "fn f() -> String {\n    let v = vec![1, 2];\n    format!(\"{}\", v.len())\n}\n";
        let found = lint_source("obs/span.rs", src);
        assert_eq!(rules_of(&found), ["R6", "R6", "R6"]);
        assert!(
            lint_source("obs/recorder.rs", src).is_empty(),
            "alloc is allowed off the span hot path"
        );
        let m = "fn f(x: &str) {\n    let _ = x.to_string();\n}\n";
        assert_eq!(rules_of(&lint_source("obs/span.rs", m)), ["R6"]);
    }

    #[test]
    fn hatches_suppress_with_reason_and_report_without() {
        let with = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic) -- this reason is long enough\n    x.unwrap()\n}\n";
        assert!(lint_source("decode/a.rs", with).is_empty());
        let without = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic)\n    x.unwrap()\n}\n";
        assert_eq!(rules_of(&lint_source("decode/a.rs", without)), ["HATCH"]);
        let unknown = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(nonsense) -- some reason here\n    x.unwrap()\n}\n";
        let found = lint_source("decode/a.rs", unknown);
        assert_eq!(rules_of(&found), ["R3", "HATCH"]);
    }

    #[test]
    fn findings_inside_test_modules_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let x: Option<u32> = None;\n        x.unwrap();\n    }\n}\n";
        assert!(lint_source("decode/a.rs", src).is_empty());
    }
}
